"""Chaos benchmark: method resilience under loss × crash × straggler drift.

Sweeps FedLuck against the FedPer / FedBuff baselines across escalating
fault levels — upload loss + NaN corruption (`repro.ft.LossyChannel`),
random crash windows (`repro.ft.FailureSchedule`), and a mid-run compute
slowdown (`repro.ft.StragglerDrift`) — with the aggregation-side
`UpdateSanitizer` guarding the global model. FedLuck runs with a live
`FedLuckController`, so the straggler's α drift triggers a mid-run
re-plan; the baselines ride out the same faults with their static plans.
Emits `BENCH_chaos.json` with per-cell accuracy, comm, and the full
drop/retry/replan counter block.

  PYTHONPATH=src python benchmarks/chaos_bench.py                 # full sweep
  PYTHONPATH=src python benchmarks/chaos_bench.py --smoke         # CI job
  PYTHONPATH=src python benchmarks/chaos_bench.py --out BENCH_chaos.json

Every invocation (smoke included) also runs the engine-equivalence gate: a
failure-injected FedLuck fleet must be *bitwise* identical between the
batched and sequential engines — weights, record timeline, and fault
counters. A mismatch exits nonzero so CI fails loudly.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs import log

# fault intensity grid: per-attempt loss / corruption probability, mean
# crash windows per device over the run, straggler α multiplier (device 0,
# kicking in a third of the way through)
FAULT_LEVELS = {
    "clean":  dict(loss=0.0, corrupt=0.0, crash_rate=0.0, drift=0.0),
    "mild":   dict(loss=0.1, corrupt=0.02, crash_rate=0.5, drift=2.0),
    "severe": dict(loss=0.3, corrupt=0.1, crash_rate=1.5, drift=4.0),
}

METHODS = ["fedluck", "fedper", "fedbuff"]


def _fault_kwargs(level: dict, num_devices: int, horizon: float, seed: int):
    """Fresh fault-model instances per simulator (channels are stateful)."""
    from repro.ft import FailureSchedule, LossyChannel, StragglerDrift
    kw = {}
    if level["crash_rate"] > 0:
        kw["failure_schedule"] = FailureSchedule.random(
            num_devices, horizon, rate_per_device=level["crash_rate"],
            mean_downtime=horizon / 20, seed=seed + 1)
    if level["loss"] > 0 or level["corrupt"] > 0:
        kw["channel"] = LossyChannel(loss_prob=level["loss"],
                                     corrupt_prob=level["corrupt"],
                                     seed=seed + 2)
    if level["drift"] > 0:
        kw["stragglers"] = [StragglerDrift(0, horizon / 3.0, level["drift"])]
    return kw


def _build(method: str, engine: str, level: str, *, task, num_devices: int,
           rounds: int, seed: int = 0, tracer=None, metrics=None):
    import jax
    import numpy as np

    from repro.core import compression as C
    from repro.core.aggregation import SanitizerConfig
    from repro.core.controller import FedLuckController
    from repro.core.simulator import (AFLSimulator, STRATEGY_FOR_METHOD,
                                      make_heterogeneous_devices,
                                      plan_devices)

    params = task.init_fn(jax.random.PRNGKey(seed))
    flat, _ = C.flatten_pytree(params)
    model_bits = int(np.asarray(flat).size) * 32
    profiles = make_heterogeneous_devices(num_devices, model_bits,
                                          base_alpha=0.2, seed=seed)
    # only FedLuck gets the drift-aware controller: that asymmetry IS the
    # experiment — the baselines cannot re-plan around the straggler
    ctl = (FedLuckController(1.0, k_bounds=(1, 16))
           if method == "fedluck" else None)
    specs = plan_devices(profiles, method, 1.0, k_bounds=(1, 16),
                         fixed_k=4, fixed_delta=0.1, controller=ctl)
    kw = _fault_kwargs(FAULT_LEVELS[level], num_devices, float(rounds), seed)
    return AFLSimulator(task, specs, STRATEGY_FOR_METHOD[method],
                        round_period=1.0, seed=seed, engine=engine,
                        controller=ctl, sanitizer=SanitizerConfig(tau_max=10),
                        tracer=tracer, metrics=metrics, **kw)


def run_cell(method: str, level: str, *, task, num_devices: int, rounds: int,
             seed: int = 0, engine: str = "batched", tracer=None,
             metrics=None) -> dict:
    sim = _build(method, engine, level, task=task, num_devices=num_devices,
                 rounds=rounds, seed=seed, tracer=tracer, metrics=metrics)
    h = sim.run(total_rounds=rounds, eval_every=max(1, rounds // 4))
    out = {
        "method": method,
        "level": level,
        "final_acc": round(h.final_accuracy(), 4),
        "final_loss": round(h.records[-1].loss, 4),
        "sim_time_s": round(h.records[-1].time, 3),
        "gbits": round(h.records[-1].gbits, 4),
        "counters": h.counters,
    }
    sim.close()
    return out


def equivalence_gate(task, *, num_devices: int = 4, rounds: int = 4,
                     seed: int = 0) -> bool:
    """Failure-injected batched vs sequential must be bitwise identical."""
    import numpy as np
    outs = {}
    for eng in ("batched", "sequential"):
        sim = _build("fedluck", eng, "severe", task=task,
                     num_devices=num_devices, rounds=rounds, seed=seed)
        h = sim.run(total_rounds=rounds, eval_every=2)
        outs[eng] = (np.asarray(sim.model.w).copy(),
                     [(r.time, r.round, r.loss, r.gbits, r.drops)
                      for r in h.records],
                     sim.fault_counters())
        sim.close()
    b, s = outs["batched"], outs["sequential"]
    return bool(np.array_equal(b[0], s[0])) and b[1] == s[1] and b[2] == s[2]


def run_bench(smoke: bool = False, seed: int = 0, tracer=None,
              metrics=None) -> dict:
    from repro.models.small import make_task
    task = make_task("mlp_micro", num_samples=2000, test_samples=200,
                     batch_size=32, seed=seed)
    report = {"bench": "chaos_resilience_sweep", "backend": "cpu",
              "sanitizer": "nonfinite guard + tau_max=10",
              "fault_levels": FAULT_LEVELS}
    if smoke:
        report["mode"] = "smoke"
        num_devices, rounds = 4, 4
        methods, levels = ["fedluck"], ["severe"]
    else:
        report["mode"] = "full"
        num_devices, rounds = 8, 16
        methods, levels = METHODS, list(FAULT_LEVELS)
    report["devices"], report["rounds"] = num_devices, rounds
    cells = []
    first = True
    for method in methods:
        for level in levels:
            log.status(f"[chaos_bench] {method} / {level} ...")
            # obs instrumentation attaches to the first cell only — one
            # run per trace keeps the Perfetto timeline readable
            cells.append(run_cell(
                method, level, task=task, num_devices=num_devices,
                rounds=rounds, seed=seed,
                tracer=tracer if first else None,
                metrics=metrics if first else None))
            first = False
    report["cells"] = cells
    log.status("[chaos_bench] engine equivalence gate ...")
    report["equivalence_ok"] = equivalence_gate(task, seed=seed)
    return report


def smoke_rows():
    """CSV rows for benchmarks.run integration: name,us_per_call,derived."""
    rep = run_bench(smoke=True)
    rows = []
    for c in rep["cells"]:
        rows.append((f"chaos_{c['method']}_{c['level']}", 0.0,
                     f"acc={c['final_acc']} "
                     f"drops={c['counters']['drops_total']}"))
    rows.append(("chaos_equivalence", 0.0,
                 "bitwise" if rep["equivalence_ok"] else "FAILED"))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="one fedluck/severe cell + equivalence gate (CI)")
    ap.add_argument("--out", default="",
                    help="write the JSON report here (default: stdout only)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default="",
                    help="write a Perfetto/Chrome trace of the first cell "
                         "(open at ui.perfetto.dev)")
    ap.add_argument("--metrics-out", default="",
                    help="write the first cell's metrics snapshot JSON")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress status lines (JSON report still printed)")
    args = ap.parse_args(argv)
    log.set_quiet(args.quiet)

    tracer = metrics = None
    if args.trace_out:
        from repro.obs import Tracer
        tracer = Tracer()
    if args.metrics_out:
        from repro.obs import MetricsRegistry
        metrics = MetricsRegistry()

    report = run_bench(smoke=args.smoke, seed=args.seed, tracer=tracer,
                       metrics=metrics)
    text = json.dumps(report, indent=1)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
        log.status(f"[chaos_bench] wrote {args.out}")
    if tracer is not None:
        from repro.obs import PerfettoExporter
        PerfettoExporter().export(tracer, args.trace_out)
        log.status(f"[chaos_bench] wrote trace: {args.trace_out} "
                   f"({len(tracer)} events)")
    if metrics is not None:
        metrics.to_json(args.metrics_out, extra={"bench": "chaos_bench"})
        log.status(f"[chaos_bench] wrote metrics: {args.metrics_out}")

    if not report["equivalence_ok"]:
        print("[chaos_bench] FAIL: batched and sequential engines disagree "
              "under injected failures", file=sys.stderr)
        return 1
    # every faulted cell must have survived with a finite model
    import math
    bad = [c for c in report["cells"] if not math.isfinite(c["final_loss"])]
    if bad:
        print(f"[chaos_bench] FAIL: non-finite final loss in {bad}",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
