"""Roofline analysis from the dry-run artifacts (results/dryrun/*.json).

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
cost_analysis() numbers are PER-DEVICE (the compiled module is the SPMD
per-device program), so:

    t_compute = flops_per_device / 197e12
    t_memory  = bytes_per_device / 819e9
    t_coll    = collective_bytes_per_device / 50e9
              (≡ global_collective_bytes / (chips × link_bw))

MODEL_FLOPS = 6·N·D for training (N = params, D = tokens; N_active for
MoE), 2·N·D for inference. useful = MODEL_FLOPS/(chips·peak); the roofline
fraction reported is useful / max(term) — an MFU upper bound from the
compiled schedule.

  PYTHONPATH=src python -m benchmarks.roofline [--mesh single] [--md out.md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

SHAPE_TOKENS = {  # D per step (global)
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,          # one token × batch
    "long_500k": 1,
}

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "dryrun")


def model_flops(rec: dict) -> float:
    n = rec["active_params"]
    d = SHAPE_TOKENS[rec["shape"]]
    mult = 6.0 if rec["kind"] == "train" else 2.0
    return mult * n * d


def analyze(rec: dict) -> dict:
    chips = rec["n_devices"]
    fl = rec["cost"]["flops_per_device"]
    by = rec["cost"]["bytes_accessed_per_device"]
    cb = rec["cost"]["collective_bytes_per_device"]
    t_c = fl / PEAK_FLOPS
    t_m = by / HBM_BW
    t_x = cb / LINK_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])
    mf = model_flops(rec)
    useful = mf / (chips * PEAK_FLOPS)
    frac = useful / max(t_c, t_m, t_x, 1e-30)
    mem = rec["memory"]
    hbm = ((mem["argument_bytes"] or 0) + (mem["temp_bytes"] or 0)
           + (mem["output_bytes"] or 0) - (mem["alias_bytes"] or 0))
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute": t_c, "t_memory": t_m, "t_collective": t_x,
        "dominant": dom[0], "roofline_fraction": frac,
        "model_flops": mf, "hlo_flops_global": fl * chips,
        "useful_ratio": mf / max(fl * chips, 1e-30),
        "hbm_gib": hbm / 2 ** 30,
        "fits_16g": hbm <= 16 * 2 ** 30,
    }


def suggestion(a: dict) -> str:
    if a["dominant"] == "collective":
        return ("shrink TP traffic: bf16 collectives, sequence-parallel "
                "norm/MLP regions, or trade TP for FSDP on this mesh")
    if a["dominant"] == "memory":
        if a["shape"].startswith("decode") or a["shape"] == "long_500k":
            return ("decode is KV-bandwidth-bound: quantize cache to int8, "
                    "shard S further, or batch more tokens per pass")
        return "raise arithmetic intensity: fuse elementwise chains, " \
               "lift remat policy to save dots"
    return "compute-bound: reduce remat recompute or causal-mask waste"


def load_all(mesh: str | None):
    out = []
    for p in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(p) as f:
            rec = json.load(f)
        if rec.get("status") != "ok" or "cost" not in rec:
            continue
        if mesh and rec["mesh"] != mesh:
            continue
        if rec.get("variant", {}).get("tag"):
            continue   # hillclimb variants are reported in §Perf, not here
        out.append(analyze(rec))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--md", default="")
    args = ap.parse_args(argv)
    rows = load_all(args.mesh or None)
    lines = [
        "| arch | shape | t_comp(s) | t_mem(s) | t_coll(s) | bound | "
        "MODEL/HLO | roofline | HBM GiB | next lever |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for a in sorted(rows, key=lambda r: r["roofline_fraction"]):
        lines.append(
            f"| {a['arch']} | {a['shape']} | {a['t_compute']:.3g} "
            f"| {a['t_memory']:.3g} | {a['t_collective']:.3g} "
            f"| {a['dominant']} | {a['useful_ratio']:.2f} "
            f"| {a['roofline_fraction']:.1%} | {a['hbm_gib']:.1f} "
            f"| {suggestion(a)} |")
    text = "\n".join(lines)
    print(text)
    if args.md:
        with open(args.md, "w") as f:
            f.write(text + "\n")


if __name__ == "__main__":
    main()
