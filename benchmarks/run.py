"""Benchmark harness: one function per paper table/figure + kernel micro-
benchmarks. Prints ``name,us_per_call,derived`` CSV (spec format).

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig2 tab2  # subset
"""
import sys
import traceback


def main() -> None:
    from benchmarks.paper_tables import (fig1_motivation_grid,
                                         fig2_time_to_accuracy,
                                         fig3_comm_consumption, tab1_noniid,
                                         tab2_joint_vs_single)
    from benchmarks.kernel_bench import (kernel_microbench, podsync_rows,
                                         sync_crossover)
    from benchmarks.sim_bench import smoke_rows as sim_smoke_rows
    from benchmarks.chaos_bench import smoke_rows as chaos_smoke_rows

    benches = {
        "fig1": fig1_motivation_grid,
        "fig2": fig2_time_to_accuracy,
        "fig3": fig3_comm_consumption,
        "tab1": tab1_noniid,
        "tab2": tab2_joint_vs_single,
        "kernels": kernel_microbench,
        "sync": sync_crossover,
        "podsync": podsync_rows,
        "sim": sim_smoke_rows,
        "chaos": chaos_smoke_rows,
    }
    picks = sys.argv[1:] or list(benches)
    print("name,us_per_call,derived")
    for name in picks:
        try:
            for row in benches[name]():
                n, us, derived = row
                print(f"{n},{us:.1f},{derived}", flush=True)
        except Exception:
            traceback.print_exc()
            print(f"{name},nan,FAILED", flush=True)


if __name__ == '__main__':
    main()
