"""One benchmark per paper table/figure (Sec. 4), on the simulator with
synthetic stand-in data. Each returns (name, us_per_call, derived) rows —
us_per_call is the wall-clock per simulated round; `derived` carries the
paper-comparable headline number."""
from __future__ import annotations

import time

import numpy as np


def _setup(noise=1.2, devices=5, samples=2000, seed=0):
    import jax
    from repro.core import compression as C
    from repro.core.simulator import make_heterogeneous_devices
    from repro.models.small import make_task
    task = make_task("mlp_fmnist", num_samples=samples, test_samples=400,
                     batch_size=32, noise=noise, seed=seed)
    params = task.init_fn(jax.random.PRNGKey(seed))
    flat, _ = C.flatten_pytree(params)
    profiles = make_heterogeneous_devices(devices, flat.size * 32,
                                          base_alpha=0.02, seed=seed)
    return task, profiles


def _sim(task, profiles, method, rounds=25, *, fixed_k=5, fixed_delta=0.1,
         k_bounds=(1, 20), noniid=False, seed=0, plan_override=None):
    from repro.core.factor import Plan
    from repro.core.simulator import (AFLSimulator, DeviceSpec,
                                      STRATEGY_FOR_METHOD, plan_devices)
    from repro.data.partition import dirichlet_partition
    if plan_override is not None:
        k, delta = plan_override
        specs = [DeviceSpec(p, Plan(k, delta, 0.0,
                                    k * p.alpha + delta * p.beta, 0), "topk")
                 for p in profiles]
        strategy = "periodic"
    else:
        specs = plan_devices(profiles, method, 1.0, k_bounds=k_bounds,
                             fixed_k=fixed_k, fixed_delta=fixed_delta)
        strategy = STRATEGY_FOR_METHOD[method]
    kw = {"strategy_kwargs": {"buffer_size": 3}} if method == "fedbuff" \
        else {}
    idx = None
    if noniid:
        idx = dirichlet_partition(task.dataset.labels, len(profiles),
                                  alpha=1.0, seed=seed)
    sim = AFLSimulator(task, specs, strategy, round_period=1.0, eta_l=0.05,
                       seed=seed, client_indices=idx, **kw)
    t0 = time.time()
    h = sim.run(total_rounds=rounds, eval_every=2)
    wall = time.time() - t0
    return h, wall / max(1, rounds) * 1e6


def fig1_motivation_grid():
    """Fig. 1: rounds-to-target over a (k, δ) grid — the motivation dilemma.
    derived = slowest/fastest convergence ratio (paper: up to ~3×/11×)."""
    task, profiles = _setup()
    target, cap = 0.70, 40
    rows, grid = [], {}
    total_us = []
    for k in (2, 8, 20):
        for delta in (0.005, 0.05, 0.5):
            h, us = _sim(task, profiles, "grid", rounds=cap,
                         plan_override=(k, delta))
            r = next((rec.round for rec in h.records
                      if rec.accuracy >= target), None)
            grid[(k, delta)] = r
            total_us.append(us)
    finite = [v for v in grid.values() if v is not None]
    if not finite:
        return [("fig1_grid_rounds_ratio", np.mean(total_us), "n/a")]
    # settings that never reach the target count as the round cap
    worst = max(v if v is not None else cap for v in grid.values())
    ratio = worst / max(1, min(finite))
    detail = ";".join(f"k{k}d{d}={v}" for (k, d), v in grid.items())
    return [("fig1_grid_rounds_ratio", np.mean(total_us),
             f"{ratio:.1f}x [{detail}]")]


def fig2_time_to_accuracy():
    """Fig. 2: elapsed simulated time to target accuracy, 5 methods.
    derived = FedLuck's average time saving vs baselines (paper: 55%)."""
    task, profiles = _setup()
    target = 0.75
    out, times = [], {}
    for m in ("fedluck", "fedper", "fedbuff", "fedasync", "fedavg_topk"):
        h, us = _sim(task, profiles, m, rounds=40)
        t = h.time_to_accuracy(target)
        times[m] = t
        out.append((f"fig2_time_to_acc_{m}", us,
                    f"{t:.2f}s" if t else "n/a"))
    base = [v for k, v in times.items() if k != "fedluck" and v]
    if times["fedluck"] and base:
        saving = 1 - times["fedluck"] / np.mean(base)
        out.append(("fig2_fedluck_time_saving", 0.0, f"{saving:.0%}"))
    return out


def fig3_comm_consumption():
    """Fig. 3: communication (Gbit) to target accuracy, 5 methods.
    derived = FedLuck's average comm saving vs baselines (paper: 56%)."""
    task, profiles = _setup()
    target = 0.75
    out, bits = [], {}
    for m in ("fedluck", "fedper", "fedbuff", "fedasync", "fedavg_topk"):
        h, us = _sim(task, profiles, m, rounds=40)
        b = h.bits_to_accuracy(target)
        bits[m] = b
        out.append((f"fig3_comm_{m}", us, f"{b:.4f}Gb" if b else "n/a"))
    base = [v for k, v in bits.items() if k != "fedluck" and v]
    if bits["fedluck"] and base:
        saving = 1 - bits["fedluck"] / np.mean(base)
        out.append(("fig3_fedluck_comm_saving", 0.0, f"{saving:.0%}"))
    return out


def tab1_noniid():
    """Tab. 1: Dirichlet(1.0) non-IID — time & comm to target, FedLuck vs
    baselines."""
    task, profiles = _setup()
    target = 0.70
    out = []
    for m in ("fedluck", "fedper", "fedbuff", "fedasync", "fedavg_topk"):
        h, us = _sim(task, profiles, m, rounds=40, noniid=True)
        t = h.time_to_accuracy(target)
        b = h.bits_to_accuracy(target)
        out.append((f"tab1_noniid_{m}", us,
                    f"t={t:.2f}s;comm={b:.4f}Gb" if t else "n/a"))
    return out


def tab2_joint_vs_single():
    """Tab. 2: FedLuck vs Opt.CR (fixed k) vs Opt.LF (fixed δ) — top-1
    accuracy at a fixed simulated-time budget."""
    task, profiles = _setup()
    out = []
    for m in ("fedluck", "opt_cr", "opt_lf"):
        h, us = _sim(task, profiles, m, rounds=20)
        out.append((f"tab2_{m}_final_acc", us,
                    f"{h.final_accuracy():.3f}"))
    return out
