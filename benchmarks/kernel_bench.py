"""Kernel microbenchmarks (interpret mode on CPU — relative numbers only;
the BlockSpec tiling targets TPU VMEM). Compares the Pallas pipeline with
the pure-jnp oracle and the exact lax.top_k path."""
from __future__ import annotations

import time

import numpy as np


def _time(fn, *args, iters=3):
    import jax
    fn(*args)                      # compile
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def kernel_microbench():
    import jax
    import jax.numpy as jnp
    from repro.core.compression import topk
    from repro.kernels import ops, ref

    rows = []
    rng = np.random.RandomState(0)
    for d in (1 << 16, 1 << 20):
        g = jnp.asarray(rng.randn(d).astype(np.float32))
        res = jnp.zeros(d)

        us = _time(lambda g, r: ops.topk_compress(g, r, rate=0.01,
                                                  interpret=True), g, res)
        rows.append((f"pallas_topk_compress_d{d}", us, "interpret"))

        exact = jax.jit(lambda g: topk(g, 0.01).dense())
        rows.append((f"exact_lax_topk_d{d}", _time(exact, g), "oracle"))

        mu = jnp.zeros(d)
        us = _time(lambda w, m, gg: ops.momentum_update(
            w, m, gg, lr=0.01, interpret=True), g, mu, g)
        rows.append((f"pallas_fused_momentum_d{d}", us, "interpret"))

        unfused = jax.jit(lambda w, m, gg: ref.ref_fused_momentum(
            w, m, gg, lr=0.01))
        rows.append((f"unfused_momentum_d{d}", _time(unfused, g, mu, g),
                     "oracle"))
    return rows


def sync_crossover():
    """δ-adaptive collective: analytic wire bytes per sync vs δ (documents
    the sparse/dense crossover used by dist.collectives)."""
    from repro.dist.collectives import all_gather_bytes, density_crossover
    d, P = 100_000_000, 2
    rows = []
    for rate in (1e-4, 1e-3, 1e-2, density_crossover(P), 0.5, 1.0):
        b = all_gather_bytes(d, P, rate)
        rows.append((f"sync_wire_bytes_delta{rate:g}", 0.0,
                     f"{b/1e6:.1f}MB"))
    return rows
