"""Kernel microbenchmarks (interpret mode on CPU — relative numbers only;
the BlockSpec tiling targets TPU VMEM). Compares the Pallas pipeline with
the pure-jnp oracle and the exact lax.top_k path.

The pod-sync section measures the compact (values, indices, count) wire
format of `dist.collectives.make_pod_sync` across the density crossover on
an 8-device host mesh (P=4 pods × 2 shards): per-device bytes-on-wire from
the *actual payload arrays*, the analytic `all_gather_bytes` model, the
dense-carrier cost, and wall time per sync for both paths — plus the
compact-vs-reference equivalence gate (fp32 params, bitwise EF residuals).
Run directly (device count is forced before jax imports):

  PYTHONPATH=src python benchmarks/kernel_bench.py --smoke
  PYTHONPATH=src python benchmarks/kernel_bench.py --out BENCH_podsync.json
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import numpy as np


def _time(fn, *args, iters=3):
    import jax
    fn(*args)                      # compile
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def kernel_microbench():
    import jax
    import jax.numpy as jnp
    from repro.core.compression import topk
    from repro.kernels import ops, ref

    rows = []
    rng = np.random.RandomState(0)
    for d in (1 << 16, 1 << 20):
        g = jnp.asarray(rng.randn(d).astype(np.float32))
        res = jnp.zeros(d)

        us = _time(lambda g, r: ops.topk_compress(g, r, rate=0.01,
                                                  interpret=True), g, res)
        rows.append((f"pallas_topk_compress_d{d}", us, "interpret"))

        exact = jax.jit(lambda g: topk(g, 0.01).dense())
        rows.append((f"exact_lax_topk_d{d}", _time(exact, g), "oracle"))

        mu = jnp.zeros(d)
        us = _time(lambda w, m, gg: ops.momentum_update(
            w, m, gg, lr=0.01, interpret=True), g, mu, g)
        rows.append((f"pallas_fused_momentum_d{d}", us, "interpret"))

        unfused = jax.jit(lambda w, m, gg: ref.ref_fused_momentum(
            w, m, gg, lr=0.01))
        rows.append((f"unfused_momentum_d{d}", _time(unfused, g, mu, g),
                     "oracle"))
    return rows


def sync_crossover():
    """δ-adaptive collective: analytic wire bytes per sync vs δ (documents
    the sparse/dense crossover used by dist.collectives)."""
    from repro.dist.collectives import all_gather_bytes, density_crossover
    d, P = 100_000_000, 2
    rows = []
    for rate in (1e-4, 1e-3, 1e-2, density_crossover(P), 0.5, 1.0):
        b = all_gather_bytes(d, P, rate, n_blocks=12_500)  # blk = 8192
        rows.append((f"sync_wire_bytes_delta{rate:g}", 0.0,
                     f"{b/1e6:.1f}MB"))
    return rows


# ---------------------------------------------------------------- pod-sync
def run_podsync(smoke: bool = False) -> tuple[dict, list[str]]:
    """Sweep the compact pod-sync across the density crossover.

    Returns (report, failures). Needs >= 8 jax devices (use `main`, which
    forces the host platform device count before importing jax).
    """
    import jax
    import jax.numpy as jnp
    import repro  # noqa: F401  (installs the jax compat shims)
    from repro.dist import collectives as col
    from repro.kernels import ops

    n_pods, n_data, n_model = 4, 2, 1
    mesh = jax.make_mesh(
        (n_pods, n_data, n_model), ("pod", "data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 3)
    n_shards = n_data * n_model
    if smoke:
        nb, blk, rates, iters, rounds = 8, 128, (0.05, 0.4), 2, 3
    else:
        nb, blk = 64, 512
        rates = (0.01, 0.02, 0.05, 0.1, 0.2, 0.25, 0.4, 0.6)
        iters, rounds = 3, 3
    dim = nb * blk
    nbl = nb // n_shards
    dim_local = dim // n_shards
    crossover = col.density_crossover(n_pods)
    rng = np.random.RandomState(0)
    params = jnp.asarray(rng.randn(nb, blk).astype(np.float32))
    deltas = jnp.asarray(rng.randn(n_pods, nb, blk).astype(np.float32))
    zeros = jnp.zeros((n_pods, nb, blk), jnp.float32)

    def wall(sync):
        fn = jax.jit(sync)
        out = fn(params, deltas, zeros)           # compile
        jax.block_until_ready(out)
        t0 = time.time()
        for _ in range(iters):
            out = fn(params, deltas, zeros)
        jax.block_until_ready(out)
        return (time.time() - t0) / iters * 1e6

    failures: list[str] = []
    cells = []
    for rate in rates:
        compact = col.make_pod_sync(mesh, dim, rate=rate, n_blocks=nb,
                                    wire="compact")
        reference = col.make_pod_sync(mesh, dim, rate=rate, n_blocks=nb,
                                      wire="reference")
        dense = col.make_pod_sync(mesh, dim, rate=rate, n_blocks=nb,
                                  wire="dense")
        auto = col.make_pod_sync(mesh, dim, rate=rate, n_blocks=nb)

        # measured bytes: the concrete payload arrays one shard ships to
        # each of the P-1 peers (values + indices + count headers)
        acc_shard = deltas[0, :nbl].astype(jnp.float32)
        v, i, c, _ = ops.compact_shard_topk(acc_shard,
                                            budget=compact.wire.budget)
        measured = (n_pods - 1) * (np.asarray(v).nbytes
                                   + np.asarray(i).nbytes
                                   + np.asarray(c).nbytes)
        model = col.all_gather_bytes(dim_local, n_pods, rate, n_blocks=nbl)
        dense_bytes = 2.0 * (n_pods - 1) / n_pods * dim_local * 4

        # equivalence gate: compact vs dense-carrier reference, EF carried
        pc, rc = params, zeros
        pr, rr = params, zeros
        jc, jr = jax.jit(compact), jax.jit(reference)
        for rnd in range(rounds):
            d_r = deltas if rnd == 0 else jnp.roll(deltas, rnd, axis=0)
            pc, rc = jc(pc, d_r, rc)
            pr, rr = jr(pr, d_r, rr)
        params_close = bool(np.allclose(np.asarray(pc), np.asarray(pr),
                                        rtol=1e-5, atol=1e-6))
        res_equal = bool(jnp.array_equal(rc, rr))
        if not (params_close and res_equal):
            failures.append(f"equivalence δ={rate}: params_close="
                            f"{params_close} res_equal={res_equal}")
        if rate < crossover and abs(measured - model) > 0.05 * model:
            failures.append(f"wire model mismatch δ={rate}: measured="
                            f"{measured}B model={model}B")

        cells.append({
            "rate": rate,
            "auto_path": auto.path,
            "budget_per_block": compact.wire.budget,
            "measured_bytes_per_device": int(measured),
            "model_bytes_per_device": float(model),
            "dense_bytes_per_device": float(dense_bytes),
            "compact_over_dense": round(measured / dense_bytes, 4),
            "wall_us_compact": round(wall(compact), 1),
            "wall_us_dense": round(wall(dense), 1),
            "params_match_reference": params_close,
            "residuals_bitwise_reference": res_equal,
        })

    by_rate = {c["rate"]: c for c in cells}
    if 0.05 in by_rate:
        c05 = by_rate[0.05]
        ratio = c05["dense_bytes_per_device"] / \
            c05["measured_bytes_per_device"]
        if ratio < 4.0:
            failures.append(f"δ=0.05 compact only {ratio:.2f}x smaller "
                            "than dense (need >= 4x)")

    report = {
        "bench": "podsync_wire_bytes",
        "mode": "smoke" if smoke else "full",
        "backend": jax.default_backend(),
        "mesh": {"pod": n_pods, "data": n_data, "model": n_model},
        "dim": dim, "n_blocks": nb, "blk": blk,
        "dim_per_shard": dim_local,
        "density_crossover": crossover,
        "unit": "bytes per device per sync; interpret-mode wall us "
                "(relative only on CPU)",
        "methodology": "measured bytes come from the concrete compact "
                       "payload arrays (values+indices+count headers) "
                       f"x (P-1) peers; equivalence gate runs {rounds} "
                       "EF rounds compact vs dense-carrier reference",
        "cells": cells,
        "failures": failures,
    }
    return report, failures


def podsync_rows():
    """CSV rows for benchmarks.run: re-executes this file in a subprocess
    (the pod mesh needs XLA_FLAGS set before jax initializes)."""
    out = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--smoke", "--quiet"],
        capture_output=True, text=True, timeout=900)
    if out.returncode != 0:
        raise RuntimeError(f"podsync smoke failed:\n{out.stderr[-2000:]}")
    rep = json.loads(out.stdout)
    rows = []
    for c in rep["cells"]:
        rows.append((f"podsync_bytes_delta{c['rate']:g}",
                     c["wall_us_compact"],
                     f"{c['measured_bytes_per_device']}B/"
                     f"{int(c['dense_bytes_per_device'])}B"))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny dim / two rates (CI smoke job)")
    ap.add_argument("--out", default="",
                    help="write the JSON report here (default: stdout only)")
    ap.add_argument("--quiet", action="store_true",
                    help="print only the JSON report (podsync_rows parsing)")
    args = ap.parse_args(argv)

    # the pod mesh needs 8 host devices; must be set before jax imports
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
    report, failures = run_podsync(smoke=args.smoke)
    text = json.dumps(report, indent=1)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
        if not args.quiet:
            print(f"[kernel_bench] wrote {args.out}", file=sys.stderr)
    if failures:
        print("[kernel_bench] podsync FAIL:\n  " + "\n  ".join(failures),
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
