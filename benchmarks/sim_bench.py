"""Simulator throughput benchmark: events/sec and wall-clock per sim-second.

Measures the batched device-resident engine (`AFLSimulator(engine="batched")`)
against the sequential pre-batching reference path on periodic-FedLuck
fleets, and emits `BENCH_simulator.json` — the perf-trajectory baseline the
ROADMAP simulator-performance item calls for.

  PYTHONPATH=src python benchmarks/sim_bench.py                # full run
  PYTHONPATH=src python benchmarks/sim_bench.py --smoke        # tiny CI fleet
  PYTHONPATH=src python benchmarks/sim_bench.py --out BENCH_simulator.json

Methodology: every measurement is steady-state — a short warmup segment
first runs both engines through their jit compiles, then the reported
`wall_s` covers exactly `rounds` simulated rounds. Warmup wall time is
reported separately as `warm_s`.

The headline is the engine-throughput configuration: a 100-device /
50-round periodic-FedLuck fleet on the compute-light `mlp_micro` task with
slow edge devices (base_alpha=0.2 → small k*). Per-cycle model compute is
negligible there, so the number isolates what this benchmark is about —
event-loop + dispatch throughput, where the batched engine must beat the
sequential path by >= 5x. The fleet sweep adds 10/50/200-device scaling
rows plus a compute-bound `mlp_fmnist` row (where both engines spend most
wall time in identical local-round FLOPs on one core, so the honest
speedup is small) and an error-feedback row exercising the device-resident
stacked-residual path.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.obs import log

# plan-time k grid: collapses the number of distinct compiled local-round
# shapes (the batched engine jits one vmapped cycle per (k, bucket) pair)
K_GRID = [1, 2, 3, 4, 6, 8, 12, 16, 24, 30]


def _build_sim(engine: str, num_devices: int, *, task, seed: int = 0,
               error_feedback: bool = False, k_max: int = 30,
               base_alpha: float = 0.2, prefetch: int = 0):
    from repro.core import compression as C
    from repro.core.simulator import (AFLSimulator, make_heterogeneous_devices,
                                      plan_devices)
    import jax
    import numpy as np

    params = task.init_fn(jax.random.PRNGKey(seed))
    flat, _ = C.flatten_pytree(params)
    model_bits = int(np.asarray(flat).size) * 32
    profiles = make_heterogeneous_devices(num_devices, model_bits,
                                          base_alpha=base_alpha, seed=seed)
    specs = plan_devices(profiles, "fedluck", 1.0, k_bounds=(1, k_max),
                         error_feedback=error_feedback, k_grid=K_GRID)
    return AFLSimulator(task, specs, "periodic", round_period=1.0,
                        seed=seed, engine=engine, prefetch=prefetch)


def measure(engine: str, num_devices: int, rounds: int, *, task,
            error_feedback: bool = False, k_max: int = 30,
            base_alpha: float = 0.2, warmup_rounds: int = 5,
            prefetch: int = 0) -> dict:
    sim = _build_sim(engine, num_devices, task=task,
                     error_feedback=error_feedback, k_max=k_max,
                     base_alpha=base_alpha, prefetch=prefetch)
    t0 = time.perf_counter()
    sim.run(total_rounds=warmup_rounds, eval_every=0)
    warm = time.perf_counter() - t0
    ev0 = sim.events_processed
    t0 = time.perf_counter()
    hist = sim.run(total_rounds=warmup_rounds + rounds, eval_every=0)
    wall = time.perf_counter() - t0
    sim.close()
    events = sim.events_processed - ev0
    sim_time = hist.records[-1].time
    return {
        "engine": engine,
        "devices": num_devices,
        "rounds": rounds,
        "error_feedback": error_feedback,
        "warm_s": round(warm, 3),
        "wall_s": round(wall, 3),
        "events": events,
        "events_per_sec": round(events / wall, 2),
        "wall_per_sim_sec": round(wall / sim_time, 4) if sim_time else None,
        "sim_time_s": round(float(sim_time), 3),
        "final_acc": round(hist.final_accuracy(), 4),
        # resilience telemetry (zero on clean fleets): crash/channel drops,
        # retry counts, sanitizer rejections — see AFLSimulator.fault_counters
        "counters": sim.fault_counters(),
    }


def _pair(num_devices: int, rounds: int, *, task, ef: bool = False,
          k_max: int = 30, base_alpha: float = 0.2, warmup_rounds: int = 5,
          prefetch: int = 0, skip_sequential: bool = False) -> dict:
    out = {"devices": num_devices, "rounds": rounds, "error_feedback": ef,
           "task": task.name}
    for eng in ("batched",) if skip_sequential else ("batched", "sequential"):
        log.status(f"[sim_bench] task={task.name} devices={num_devices} "
                   f"rounds={rounds} ef={ef} {eng} ...")
        out[eng] = measure(eng, num_devices, rounds, task=task,
                           error_feedback=ef, k_max=k_max,
                           base_alpha=base_alpha, warmup_rounds=warmup_rounds,
                           prefetch=prefetch)
    if not skip_sequential:
        out["speedup_wall"] = round(
            out["sequential"]["wall_s"] / out["batched"]["wall_s"], 2)
    return out


def run_bench(smoke: bool = False, seed: int = 0, prefetch: int = 0) -> dict:
    from repro.models.small import make_task

    micro = make_task("mlp_micro", num_samples=2000, test_samples=200,
                      batch_size=32, seed=seed)
    report = {"bench": "simulator_events_per_sec",
              "strategy": "periodic (FedLuck plans)", "backend": "cpu",
              "unit": "simulated events/sec; wall seconds per sim second",
              "methodology": "steady-state: jit warmup excluded (warm_s)",
              "prefetch": prefetch}
    if smoke:
        report["mode"] = "smoke"
        report["headline"] = _pair(4, 3, task=micro, warmup_rounds=2,
                                   prefetch=prefetch)
        report["fleets"] = [report["headline"]]
        return report

    report["mode"] = "full"
    # acceptance headline: 100-device / 50-round periodic-FedLuck run on the
    # engine-throughput (compute-light) configuration
    report["headline"] = _pair(100, 50, task=micro, prefetch=prefetch)
    fleets = [_pair(10, 20, task=micro, prefetch=prefetch),
              _pair(50, 20, task=micro, prefetch=prefetch),
              _pair(200, 20, task=micro, prefetch=prefetch)]
    # EF exercises the device-resident stacked-residual path
    fleets.append(_pair(50, 10, task=micro, ef=True, prefetch=prefetch))
    # prefetch row: background stacking thread (pays off with spare cores)
    fleets.append(_pair(50, 10, task=micro, prefetch=max(1, prefetch)))
    # compute-bound regime: both engines pay identical local-round FLOPs on
    # one core, so the gap narrows to the eliminated dispatch/sort overhead
    fmnist = make_task("mlp_fmnist", num_samples=2000, test_samples=200,
                       batch_size=32, seed=seed)
    fleets.append(_pair(20, 10, task=fmnist, warmup_rounds=3))
    report["fleets"] = fleets
    return report


def smoke_rows():
    """CSV rows for benchmarks.run integration: name,us_per_call,derived."""
    rep = run_bench(smoke=True)
    rows = []
    for eng in ("batched", "sequential"):
        r = rep["headline"][eng]
        us_per_event = 1e6 * r["wall_s"] / max(1, r["events"])
        rows.append((f"sim_{eng}_d{r['devices']}", us_per_event,
                     f"{r['events_per_sec']}ev/s"))
    rows.append(("sim_speedup", 0.0, f"{rep['headline']['speedup_wall']}x"))
    return rows


def _obs_sim(engine: str, num_devices: int, *, task, seed: int,
             tracer=None, metrics=None):
    """Fault-injected instrumented simulator: a mildly lossy channel plus
    crash windows and a sanitizer, so the exported trace/metrics carry
    nonzero retry/drop/corruption activity."""
    from repro.core import compression as C
    from repro.core.aggregation import SanitizerConfig
    from repro.core.simulator import (AFLSimulator, make_heterogeneous_devices,
                                      plan_devices)
    from repro.ft import FailureSchedule, LossyChannel
    import jax
    import numpy as np

    params = task.init_fn(jax.random.PRNGKey(seed))
    flat, _ = C.flatten_pytree(params)
    model_bits = int(np.asarray(flat).size) * 32
    profiles = make_heterogeneous_devices(num_devices, model_bits,
                                          base_alpha=0.2, seed=seed)
    specs = plan_devices(profiles, "fedluck", 1.0, k_bounds=(1, 30),
                         k_grid=K_GRID)
    return AFLSimulator(
        task, specs, "periodic", round_period=1.0, seed=seed, engine=engine,
        failure_schedule=FailureSchedule.random(
            num_devices, 20.0, rate_per_device=0.5, mean_downtime=0.5,
            seed=seed + 1),
        channel=LossyChannel(loss_prob=0.25, corrupt_prob=0.05,
                             seed=seed + 2),
        sanitizer=SanitizerConfig(tau_max=16),
        tracer=tracer, metrics=metrics)


def run_obs(args) -> int:
    """Instrumented dual-engine run behind --trace-out/--metrics-out.

    Gates (any violation exits nonzero):
      * batched and sequential emit IDENTICAL event sequences;
      * engine-agnostic metric snapshots are identical;
      * exported faults.* totals equal History.counters EXACTLY per engine;
      * optional --overhead-gate: a NullTracer run (every call site
        exercised, all no-ops) stays under gate x the default wall time.
    """
    from repro.models.small import make_task
    from repro.obs import (MetricsRegistry, NullTracer, PerfettoExporter,
                           Tracer)

    rounds = 6 if args.smoke else 20
    task = make_task("mlp_micro", num_samples=2000, test_samples=200,
                     batch_size=32, seed=args.seed)
    runs = {}
    for eng in ("batched", "sequential"):
        log.status(f"[sim_bench] obs run: {eng} devices={args.devices} "
                   f"rounds={rounds} ...")
        tracer, metrics = Tracer(), MetricsRegistry()
        sim = _obs_sim(eng, args.devices, task=task, seed=args.seed,
                       tracer=tracer, metrics=metrics)
        hist = sim.run(total_rounds=rounds, eval_every=2)
        sim.close()
        snap = metrics.snapshot()
        for k, v in hist.counters.items():
            if snap["counters"].get(f"faults.{k}") != float(v):
                print(f"[sim_bench] FAIL: {eng} faults.{k}="
                      f"{snap['counters'].get(f'faults.{k}')} != "
                      f"History.counters[{k!r}]={v}", file=sys.stderr)
                return 1
        runs[eng] = {"tracer": tracer, "metrics": metrics, "hist": hist}
    b, s = runs["batched"], runs["sequential"]
    if b["tracer"].events != s["tracer"].events:
        print("[sim_bench] FAIL: engines emitted different event sequences",
              file=sys.stderr)
        return 1
    if (b["metrics"].snapshot(engine_agnostic=True)
            != s["metrics"].snapshot(engine_agnostic=True)):
        print("[sim_bench] FAIL: engine-agnostic metrics differ",
              file=sys.stderr)
        return 1
    if b["hist"].counters["retries"] == 0:
        print("[sim_bench] FAIL: fault injection produced no retries",
              file=sys.stderr)
        return 1
    if args.trace_out:
        PerfettoExporter().export(b["tracer"], args.trace_out)
        log.status(f"[sim_bench] wrote trace: {args.trace_out} "
                   f"({len(b['tracer'])} events)")
    if args.metrics_out:
        doc = {"schema": "repro.obs.metrics/v1", "bench": "sim_bench_obs",
               "devices": args.devices, "rounds": rounds,
               "batched": b["metrics"].snapshot(),
               "sequential": s["metrics"].snapshot()}
        with open(args.metrics_out, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
        log.status(f"[sim_bench] wrote metrics: {args.metrics_out}")

    if args.overhead_gate > 0:
        def wall(tracer):
            best = float("inf")
            for _ in range(3):
                sim = _obs_sim("batched", args.devices, task=task,
                               seed=args.seed, tracer=tracer)
                t0 = time.perf_counter()
                sim.run(total_rounds=rounds, eval_every=2)
                best = min(best, time.perf_counter() - t0)
                sim.close()
            return best
        plain = wall(None)          # default: guards skip every call site
        null = wall(NullTracer())   # every call site runs, all no-ops
        ratio = null / plain
        log.status(f"[sim_bench] no-op tracer overhead: {ratio:.3f}x "
                   f"(plain {plain:.3f}s, null {null:.3f}s, "
                   f"gate {args.overhead_gate}x)")
        if ratio > args.overhead_gate:
            print(f"[sim_bench] FAIL: no-op tracer overhead {ratio:.3f}x "
                  f"exceeds gate {args.overhead_gate}x", file=sys.stderr)
            return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fleet / few rounds (CI smoke job)")
    ap.add_argument("--out", default="",
                    help="write the JSON report here (default: stdout only)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prefetch", type=int, default=0,
                    help="StackedLoader prefetch depth for every fleet row "
                         "(bitwise-identical results; pays off with spare "
                         "cores)")
    ap.add_argument("--trace-out", default="",
                    help="run an instrumented fault-injected fleet and write "
                         "a Perfetto/Chrome trace (open at ui.perfetto.dev)")
    ap.add_argument("--metrics-out", default="",
                    help="write dual-engine metrics JSON from the "
                         "instrumented run")
    ap.add_argument("--overhead-gate", type=float, default=0.0,
                    help="assert a NullTracer run stays under this multiple "
                         "of the uninstrumented wall time (e.g. 1.05)")
    ap.add_argument("--devices", type=int, default=10,
                    help="fleet size for the instrumented obs run")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress status lines (JSON report still printed)")
    args = ap.parse_args(argv)
    log.set_quiet(args.quiet)

    report = run_bench(smoke=args.smoke, seed=args.seed,
                       prefetch=args.prefetch)
    text = json.dumps(report, indent=1)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
        log.status(f"[sim_bench] wrote {args.out}")

    # sanity gate so the CI smoke job fails loudly on a broken engine
    head = report["headline"]
    ok = (head["batched"]["events"] > 0
          and head["batched"]["events"] == head["sequential"]["events"]
          and abs(head["batched"]["final_acc"]
                  - head["sequential"]["final_acc"]) < 1e-6)
    if not ok:
        print("[sim_bench] FAIL: engines disagree", file=sys.stderr)
        return 1
    if args.trace_out or args.metrics_out or args.overhead_gate > 0:
        return run_obs(args)
    return 0


if __name__ == "__main__":
    sys.exit(main())
