"""Quickstart: FedLuck in ~40 lines.

1. Profile each device (α = s/local-step, β = s/full-gradient-upload).
2. The controller minimizes the key convergence factor φ(k, δ) (Eq. 14/15)
   to pick each device's local-update count k_i and top-k density δ_i.
3. Run asynchronous federated training with periodic aggregation (Alg. 1).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import compression as C
from repro.core.controller import DeviceProfile, FedLuckController
from repro.core.simulator import (AFLSimulator, DeviceSpec,
                                  make_heterogeneous_devices)
from repro.models.small import make_task

# ---- task: the paper's CNN@FMNIST (synthetic stand-in data offline).
# (swap to "cnn_fmnist" + larger k_max for the full-size run; the MLP keeps
# this quickstart under a minute on one CPU core)
task = make_task("mlp_fmnist", num_samples=2000, test_samples=400)
params = task.init_fn(jax.random.PRNGKey(0))
flat, _ = C.flatten_pytree(params)
print(f"model: d = {flat.size:,} parameters")

# ---- heterogeneous devices: α ~ U[a, 4a], bandwidth 0.25–2 Mb/s (Sec 4.3)
profiles = make_heterogeneous_devices(num=5, model_bits=flat.size * 32,
                                      base_alpha=0.02, seed=0)

# ---- FedLuck controller: solve Eq. 15 per device
controller = FedLuckController(round_period=1.0, k_bounds=(1, 20),
                               delta_bounds=(1e-3, 1.0))
devices = []
for p in profiles:
    plan = controller.register(p)
    devices.append(DeviceSpec(p, plan, compressor="topk"))
print("per-device plans (k_i, δ_i) from minimizing φ:")
print(controller.summary())

# ---- asynchronous training with periodic aggregation
sim = AFLSimulator(task, devices, "periodic", round_period=1.0,
                   eta_l=0.05, seed=0)
history = sim.run(total_rounds=20, eval_every=4)

for r in history.records:
    print(f"  t={r.time:5.1f}s  round={r.round:3d}  acc={r.accuracy:.3f}  "
          f"comm={r.gbits:.3f} Gbit")
print(f"final accuracy: {history.final_accuracy():.3f}")
