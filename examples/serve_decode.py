"""Batched serving example: prefill + greedy decode with KV/SSM caches on
two different architecture families (GQA transformer and attention-free
mamba2), smoke configs on CPU.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
import os
import subprocess
import sys

os.environ.setdefault("PYTHONPATH", "src")
for arch in ("gemma3-4b", "mamba2-780m"):
    print(f"=== serving {arch} (reduced config) ===")
    subprocess.run([sys.executable, "-m", "repro.launch.serve",
                    "--arch", arch, "--requests", "4", "--batch", "2",
                    "--prompt-len", "12", "--gen", "12"],
                   env=dict(os.environ), check=True)
