"""Paper reproduction (Figs. 2–3 shape): FedLuck vs the four baselines on
one task — elapsed simulated time and communication to target accuracy.

Run:  PYTHONPATH=src python examples/fedluck_vs_baselines.py [task]
      task ∈ {mlp_fmnist (fast, default), cnn_fmnist, lstm_sc}
"""
import sys

import jax

from repro.core import compression as C
from repro.core.simulator import (AFLSimulator, STRATEGY_FOR_METHOD,
                                  make_heterogeneous_devices, plan_devices)
from repro.models.small import make_task

task_name = sys.argv[1] if len(sys.argv) > 1 else "mlp_fmnist"
task = make_task(task_name, num_samples=2000, test_samples=400, noise=1.2)
params = task.init_fn(jax.random.PRNGKey(0))
flat, _ = C.flatten_pytree(params)
profiles = make_heterogeneous_devices(5, flat.size * 32, base_alpha=0.02,
                                      seed=0)
TARGET = 0.85

print(f"task={task_name}  d={flat.size:,}  target_acc={TARGET}")
print(f"{'method':14s} {'time-to-acc(s)':>15s} {'comm(Gbit)':>12s} "
      f"{'final acc':>10s}")
results = {}
for method in ("fedluck", "fedper", "fedbuff", "fedasync", "fedavg_topk"):
    specs = plan_devices(profiles, method, 1.0, k_bounds=(1, 20),
                         fixed_k=5, fixed_delta=0.1)
    kw = {"strategy_kwargs": {"buffer_size": 3}} if method == "fedbuff" \
        else {}
    sim = AFLSimulator(task, specs, STRATEGY_FOR_METHOD[method],
                       round_period=1.0, eta_l=0.05, seed=0, **kw)
    h = sim.run(total_rounds=30, eval_every=2)
    t = h.time_to_accuracy(TARGET)
    b = h.bits_to_accuracy(TARGET)
    results[method] = (t, b)
    print(f"{method:14s} {t if t else float('nan'):15.2f} "
          f"{b if b else float('nan'):12.4f} {h.final_accuracy():10.3f}")

t_luck, b_luck = results["fedluck"]
others_t = [v[0] for k, v in results.items() if k != "fedluck" and v[0]]
others_b = [v[1] for k, v in results.items() if k != "fedluck" and v[1]]
if t_luck and others_t:
    print(f"\nFedLuck time saving vs baseline mean: "
          f"{1 - t_luck / (sum(others_t)/len(others_t)):.0%} "
          f"(paper reports 55% on real datasets)")
if b_luck and others_b:
    print(f"FedLuck comm saving vs baseline mean: "
          f"{1 - b_luck / (sum(others_b)/len(others_b)):.0%} "
          f"(paper reports 56%)")
