"""Datacenter mapping of FedLuck (DESIGN.md §2): each "pod" runs k local
steps on its own shard of the batch, EF-top-k-compresses the pseudo-
gradient at the controller-chosen δ, and the deltas are aggregated with
the Eq. 6 server rule. Here pods are simulated serially on CPU with a
smoke-size LM; on a real cluster each pod is one slice and the aggregation
is the sparse all-reduce in repro.dist.collectives.

Run:  PYTHONPATH=src python examples/multipod_local_sgd.py
"""
import subprocess
import sys
import os

os.environ.setdefault("PYTHONPATH", "src")
subprocess.run([sys.executable, "-m", "repro.launch.train",
                "--mode", "datacenter", "--arch", "mamba2-780m",
                "--steps", "15", "--pods", "2", "--local-k-max", "8",
                "--dcn-bps", "1e11"],
               env=dict(os.environ), check=True)
