"""Lossy uplink channel: packet loss, retry/backoff, bandwidth drift.

Models the device -> server upload path of the AFL simulator as an
unreliable channel. Three independent fault axes compose:

  * **Upload loss** — each transmission attempt is lost with probability
    `loss_prob` (a float, or a `{device_id: p}` dict for per-device
    links). The sender detects the loss after `RetryPolicy.timeout`
    seconds (exponential backoff per retry) and retransmits; every
    attempt is charged full upload time *and* full payload-shape wire
    bits, so the paper's Eq. 5 communication accounting stays honest
    under retries (`charge_wire` splits the overhead into `retx_bits`
    and `lost_bits` counters once the simulator knows the payload size).
    After `max_attempts` transmissions the update is dropped and the
    device gives up (it restarts a fresh local round on the current
    model).

  * **Bandwidth drift** — `BandwidthDrift` events multiply a device's β
    from `start` on (link congestion). Effective upload time of an
    attempt beginning at time s is `rate·β·beta_multiplier(device, s)`,
    so a retransmission that straddles a drift event pays the new price.
    Observed β feeds the FedLuck controller's drift-aware re-planner.

  * **Corruption** — with probability `corrupt_prob` a delivered payload
    arrives NaN-poisoned (bit flips in transit / a faulty sender). Only
    the aggregation-side sanitizer (`repro.core.aggregation
    .UpdateSanitizer`) stands between a corrupted update and the global
    model — that interaction is exactly what the chaos tests exercise.

Determinism: every random draw comes from a per-device counter-based
stream seeded by (seed, device_id), and a device's cycles are totally
ordered in simulated time, so outcomes are independent of how the
simulator interleaves *other* devices' events. That is what keeps the
batched and sequential engines bitwise identical under channel faults.
A channel instance is stateful (streams + counters): build a fresh one
per run (or call `reset()`).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Timeout / exponential-backoff retransmission policy."""
    max_attempts: int = 4     # total transmissions, including the first
    timeout: float = 0.25     # seconds to detect a lost upload (base)
    backoff: float = 2.0      # timeout multiplier per successive retry

    def wait(self, attempt: int) -> float:
        """Detection + backoff wait after lost attempt #`attempt` (0-based)."""
        return self.timeout * self.backoff ** attempt


@dataclasses.dataclass(frozen=True)
class BandwidthDrift:
    """β multiplier applied to a device's link from `start` on."""
    device_id: int
    start: float
    beta_multiplier: float = 2.0


class LossyChannel:
    def __init__(self, *, loss_prob: float | dict = 0.0,
                 drift: list[BandwidthDrift] | None = None,
                 retry: RetryPolicy | None = None,
                 corrupt_prob: float | dict = 0.0, seed: int = 0):
        self.loss_prob = loss_prob
        self.corrupt_prob = corrupt_prob
        self.drift = sorted(drift or [], key=lambda d: d.start)
        self.retry = retry or RetryPolicy()
        self.seed = int(seed)
        # When a tracer is attached the simulator flips this on; `transmit`
        # then records each attempt's (start, end, lost) in `last_attempts`
        # so per-attempt retry spans can be emitted in simulated time. Off
        # by default — the hot path allocates nothing.
        self.trace_attempts = False
        self.last_attempts: list[tuple[float, float, bool]] = []
        self.reset()

    def reset(self) -> None:
        """Re-arm the per-device RNG streams and zero the counters."""
        self._streams: dict[int, np.random.RandomState] = {}
        self.counters = {"attempts": 0, "retries": 0, "delivered": 0,
                         "channel_dropped": 0, "corrupted": 0,
                         "retx_bits": 0.0, "lost_bits": 0.0}
        self.last_attempts = []

    # ------------------------------------------------------------- internals
    def _stream(self, device_id: int) -> np.random.RandomState:
        s = self._streams.get(device_id)
        if s is None:
            s = np.random.RandomState((self.seed * 1000003 + 977 * device_id
                                       + 12345) % (2 ** 31 - 1))
            self._streams[device_id] = s
        return s

    @staticmethod
    def _prob(p: float | dict, device_id: int) -> float:
        return float(p.get(device_id, 0.0)) if isinstance(p, dict) else float(p)

    # ------------------------------------------------------------------- api
    def beta_multiplier(self, device_id: int, t: float) -> float:
        """Product of all drift multipliers active for the device at t."""
        m = 1.0
        for d in self.drift:
            if d.start > t:
                break
            if d.device_id == device_id:
                m *= d.beta_multiplier
        return m

    def maybe_corrupt(self, device_id: int) -> bool:
        """Draw the per-cycle corruption coin (always first in the device's
        stream, before the transmission attempts, so draw order is fixed)."""
        p = self._prob(self.corrupt_prob, device_id)
        if p <= 0.0:
            return False
        hit = bool(self._stream(device_id).random_sample() < p)
        if hit:
            self.counters["corrupted"] += 1
        return hit

    def transmit(self, device_id: int, t_ready: float, base_upload: float
                 ) -> tuple[float | None, int, float]:
        """Simulate the retransmission loop for one upload.

        `base_upload` is the clean-link upload duration (rate·β seconds).
        Returns `(arrive_time, attempts, give_up_time)`: `arrive_time` is
        None when every attempt was lost, in which case `give_up_time` is
        when the sender stops retrying. All attempts consume simulated
        time; the caller charges `attempts ×` wire bits.
        """
        p = self._prob(self.loss_prob, device_id)
        trace = self.trace_attempts
        if trace:
            self.last_attempts = []
        s = t_ready
        for i in range(self.retry.max_attempts):
            dur = base_upload * self.beta_multiplier(device_id, s)
            self.counters["attempts"] += 1
            if i:
                self.counters["retries"] += 1
            lost = p > 0.0 and bool(
                self._stream(device_id).random_sample() < p)
            if trace:
                self.last_attempts.append((s, s + dur, lost))
            if not lost:
                self.counters["delivered"] += 1
                return s + dur, i + 1, s + dur
            s = s + dur + self.retry.wait(i)
        self.counters["channel_dropped"] += 1
        return None, self.retry.max_attempts, s

    def charge_wire(self, bits: float, attempts: int, delivered: bool
                    ) -> None:
        """Payload-shape wire accounting for one upload's transmissions.

        `transmit` resolves the retry schedule before the payload exists
        (it consumes only RNG streams); the simulator calls this once the
        payload size is known. Delivered uploads charge the retransmitted
        copies (attempts beyond the first) to `retx_bits`; uploads the
        channel dropped after max retries charge every attempt to
        `lost_bits`. Both engines call it at the same points, so the
        counters stay engine-identical."""
        if delivered:
            self.counters["retx_bits"] += float(bits) * (attempts - 1)
        else:
            self.counters["lost_bits"] += float(bits) * attempts
