from repro.ft.channel import BandwidthDrift, LossyChannel, RetryPolicy
from repro.ft.failures import (FailureSchedule, FailureWindow, StragglerDrift,
                               merge_overlaps)

__all__ = ["BandwidthDrift", "FailureSchedule", "FailureWindow",
           "LossyChannel", "RetryPolicy", "StragglerDrift", "merge_overlaps"]
