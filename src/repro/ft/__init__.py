from repro.ft.failures import FailureSchedule, FailureWindow, StragglerDrift

__all__ = ["FailureSchedule", "FailureWindow", "StragglerDrift"]
