"""Failure / straggler models for fault-tolerance testing.

The AFL design is inherently failure-tolerant: a dead device's update is
simply absent from S^t and aggregation proceeds (Eq. 6 averages over
whatever arrived). These helpers let tests and benchmarks inject failures
and verify that property end-to-end, and model stragglers whose compute
slows mid-run (triggering controller re-plans).

`FailureSchedule` indexes its windows per device at construction: windows
are validated (`end > start`), overlap-merged, and stored as sorted
(starts, ends) arrays so `is_down` / `recovery_time` / `lost_in_flight`
are O(log W) binary searches instead of an O(W) scan per simulator event.
Merging makes chained downtime first-class: back-to-back windows
[2, 5) + [5, 7) are one outage [2, 7) — no *new* failure begins at t=5,
so an upload that started while the device was already down is not
double-counted as "lost in flight".
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class FailureWindow:
    device_id: int
    start: float
    end: float          # device is down for t in [start, end)


def merge_overlaps(windows: list[FailureWindow]) -> list[FailureWindow]:
    """Normalize a window list: per device, sort by start and coalesce
    overlapping or touching windows ([2,5)+[5,7) -> [2,7)). Raises
    ValueError on any window with `end <= start`."""
    for w in windows:
        if not w.end > w.start:
            raise ValueError(f"FailureWindow end <= start: {w}")
    by_dev: dict[int, list[FailureWindow]] = {}
    for w in windows:
        by_dev.setdefault(w.device_id, []).append(w)
    out: list[FailureWindow] = []
    for did in sorted(by_dev):
        merged: list[list[float]] = []
        for w in sorted(by_dev[did], key=lambda w: (w.start, w.end)):
            if merged and w.start <= merged[-1][1]:
                merged[-1][1] = max(merged[-1][1], w.end)
            else:
                merged.append([w.start, w.end])
        out.extend(FailureWindow(did, s, e) for s, e in merged)
    return out


@dataclasses.dataclass
class FailureSchedule:
    windows: list[FailureWindow]

    def __post_init__(self):
        self._index: dict[int, tuple[np.ndarray, np.ndarray]] = {}
        for w in merge_overlaps(self.windows):
            self._index.setdefault(w.device_id, ([], []))
            self._index[w.device_id][0].append(w.start)
            self._index[w.device_id][1].append(w.end)
        self._index = {d: (np.asarray(s, np.float64), np.asarray(e, np.float64))
                       for d, (s, e) in self._index.items()}

    def merge_overlaps(self) -> "FailureSchedule":
        """A normalized copy whose `windows` are the merged outages."""
        return FailureSchedule(merge_overlaps(self.windows))

    def is_down(self, device_id: int, t: float) -> bool:
        idx = self._index.get(device_id)
        if idx is None:
            return False
        starts, ends = idx
        i = int(np.searchsorted(starts, t, side="right")) - 1
        return i >= 0 and t < ends[i]

    def lost_in_flight(self, device_id: int, start: float, finish: float) -> bool:
        """True if an outage begins inside (start, finish): the local
        round / upload is lost (node crash mid-round)."""
        idx = self._index.get(device_id)
        if idx is None:
            return False
        starts, _ = idx
        i = int(np.searchsorted(starts, start, side="right"))
        return i < len(starts) and starts[i] < finish

    def crash_recovery(self, device_id: int, start: float,
                       finish: float) -> float | None:
        """End of the outage that begins inside (start, finish), or None
        when no such outage exists. This is where a device whose in-flight
        upload was killed comes back up — `recovery_time(start)` would be
        wrong here, since the crash window opens *after* the cycle began."""
        idx = self._index.get(device_id)
        if idx is None:
            return None
        starts, ends = idx
        i = int(np.searchsorted(starts, start, side="right"))
        if i < len(starts) and starts[i] < finish:
            return float(ends[i])
        return None

    def recovery_time(self, device_id: int, t: float) -> float:
        """Earliest time >= t at which the device is back up. Chained
        windows are pre-merged, so this is one lookup."""
        t_rec = t
        idx = self._index.get(device_id)
        if idx is not None:
            starts, ends = idx
            i = int(np.searchsorted(starts, t, side="right")) - 1
            if i >= 0 and t < ends[i]:
                t_rec = float(ends[i])
        return max(t_rec, t + 1e-9)

    @staticmethod
    def random(num_devices: int, horizon: float, rate_per_device: float = 0.2,
               mean_downtime: float = 2.0, seed: int = 0) -> "FailureSchedule":
        rng = np.random.RandomState(seed)
        windows = []
        for d in range(num_devices):
            n = rng.poisson(rate_per_device)
            for _ in range(n):
                s = rng.uniform(0, horizon)
                windows.append(FailureWindow(d, s, s + rng.exponential(
                    mean_downtime)))
        return FailureSchedule(windows)


@dataclasses.dataclass
class StragglerDrift:
    """α multiplier applied to a device from `start` on (compute slowdown)."""
    device_id: int
    start: float
    alpha_multiplier: float = 3.0
