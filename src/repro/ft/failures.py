"""Failure / straggler models for fault-tolerance testing.

The AFL design is inherently failure-tolerant: a dead device's update is
simply absent from S^t and aggregation proceeds (Eq. 6 averages over
whatever arrived). These helpers let tests and benchmarks inject failures
and verify that property end-to-end, and model stragglers whose compute
slows mid-run (triggering controller re-plans).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class FailureWindow:
    device_id: int
    start: float
    end: float          # device is down for t in [start, end)


@dataclasses.dataclass
class FailureSchedule:
    windows: list[FailureWindow]

    def is_down(self, device_id: int, t: float) -> bool:
        return any(w.device_id == device_id and w.start <= t < w.end
                   for w in self.windows)

    def lost_in_flight(self, device_id: int, start: float, finish: float) -> bool:
        """True if a failure window begins inside (start, finish): the local
        round / upload is lost (node crash mid-round)."""
        return any(w.device_id == device_id and start < w.start < finish
                   for w in self.windows)

    def recovery_time(self, device_id: int, t: float) -> float:
        """Earliest time >= t at which the device is back up."""
        t_rec = t
        for w in sorted(self.windows, key=lambda w: w.start):
            if w.device_id == device_id and w.start <= t_rec < w.end:
                t_rec = w.end
        return max(t_rec, t + 1e-9)

    @staticmethod
    def random(num_devices: int, horizon: float, rate_per_device: float = 0.2,
               mean_downtime: float = 2.0, seed: int = 0) -> "FailureSchedule":
        rng = np.random.RandomState(seed)
        windows = []
        for d in range(num_devices):
            n = rng.poisson(rate_per_device)
            for _ in range(n):
                s = rng.uniform(0, horizon)
                windows.append(FailureWindow(d, s, s + rng.exponential(
                    mean_downtime)))
        return FailureSchedule(windows)


@dataclasses.dataclass
class StragglerDrift:
    """α multiplier applied to a device from `start` on (compute slowdown)."""
    device_id: int
    start: float
    alpha_multiplier: float = 3.0
