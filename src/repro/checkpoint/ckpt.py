"""Sharded pytree checkpointing (orbax is not installed offline).

Layout per checkpoint:
  <dir>/step_<N>/
    manifest.json   treedef + array specs (shape, dtype, path keys)
    arrays.npz      flat arrays, key = flattened pytree path

Arrays are pulled to host (fully replicated view) before writing; restore
re-places them with a target sharding if given. Writes are atomic
(tmp dir + rename) so a crash mid-save never corrupts the latest step —
this is the restart-safety contract `launch/train.py` relies on.
`CheckpointManager` adds retention, latest-step discovery and an async
(background-thread) save path so the training loop never blocks on disk.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any

import jax
import ml_dtypes
import numpy as np

# numpy can't natively serialize bf16/fp8 — stored as raw uint8 with the
# true dtype recorded in the manifest.
_EXT_DTYPES = {"bfloat16": ml_dtypes.bfloat16,
               "float8_e4m3fn": ml_dtypes.float8_e4m3fn,
               "float8_e5m2": ml_dtypes.float8_e5m2}


def _to_native(arr: np.ndarray) -> np.ndarray:
    if arr.dtype.name in _EXT_DTYPES:
        return arr.view(np.uint8)
    return arr


def _from_native(arr: np.ndarray, dtype_name: str, shape) -> np.ndarray:
    if dtype_name in _EXT_DTYPES:
        return arr.view(_EXT_DTYPES[dtype_name]).reshape(shape)
    return arr


def _flatten_with_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_pytree(tree, directory: str) -> None:
    tmp = directory + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten_with_paths(tree)
    arrays = {}
    manifest = {"keys": [], "treedef": None}
    for key, leaf in flat:
        arr = np.asarray(jax.device_get(leaf))
        manifest["keys"].append(
            {"key": key, "shape": list(arr.shape), "dtype": str(arr.dtype)})
        arrays[key] = _to_native(arr)
    # record treedef as the example pytree of keys so we can unflatten
    treedef = jax.tree_util.tree_structure(tree)
    manifest["treedef"] = str(treedef)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(directory):
        shutil.rmtree(directory)
    os.rename(tmp, directory)


def load_pytree(directory: str, like=None, shardings=None):
    """Restore. If `like` is given, restores into its treedef (and the
    arrays are placed with `shardings` — a matching pytree or None)."""
    with np.load(os.path.join(directory, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    meta = {e["key"]: e for e in manifest["keys"]}
    arrays = {k: _from_native(v, meta[k]["dtype"], meta[k]["shape"])
              if k in meta else v for k, v in arrays.items()}
    if like is None:
        return arrays  # flat dict form
    flat = _flatten_with_paths(like)
    leaves = []
    for key, leaf in flat:
        if key not in arrays:
            raise KeyError(f"checkpoint missing key {key}")
        arr = arrays[key]
        if hasattr(leaf, "dtype") and arr.dtype != np.asarray(leaf).dtype:
            arr = arr.astype(np.asarray(leaf).dtype)
        leaves.append(arr)
    treedef = jax.tree_util.tree_structure(like)
    restored = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        restored = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else x,
            restored, shardings)
    return restored


_STEP_RE = re.compile(r"^step_(\d+)$")


class CheckpointManager:
    """Retention + async save + latest-step restore."""

    def __init__(self, root: str, max_to_keep: int = 3, async_save: bool = True):
        self.root = root
        self.max_to_keep = max_to_keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(root, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree) -> None:
        if self.async_save:
            # snapshot to host synchronously (cheap vs disk), write in thread
            host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host_tree), daemon=True)
            self._thread.start()
        else:
            self._write(step, tree)

    def _write(self, step: int, tree) -> None:
        save_pytree(tree, self._dir(step))
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # --------------------------------------------------------------- restore
    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.root):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.root, name, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: int | None = None, like=None, shardings=None):
        step = self.latest_step() if step is None else step
        if step is None:
            return None
        return load_pytree(self._dir(step), like=like, shardings=shardings)

    # ------------------------------------------------------------------ util
    def _dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step}")

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.max_to_keep]:
            shutil.rmtree(self._dir(s), ignore_errors=True)
