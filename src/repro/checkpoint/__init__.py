from repro.checkpoint.ckpt import (
    save_pytree, load_pytree, CheckpointManager,
)

__all__ = ["save_pytree", "load_pytree", "CheckpointManager"]
