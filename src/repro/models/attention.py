"""Attention: GQA/MQA + RoPE + sliding window + prefix-LM, memory-bounded.

Training/prefill use a flash-style double-chunked implementation (outer
lax.map over query chunks, inner lax.scan over KV chunks with running
max/sum/accumulator in fp32) so the live logits buffer is q_chunk×kv_chunk,
never S×S — required for seq 4096 × batch 256 and 32k prefill.

Decode is a single-token dense pass written so reductions run OVER the
(possibly sequence-sharded) cache axis: under GSPMD the max/sum/contraction
over a sharded S lower to local partials + small all-reduces — i.e.
flash-decoding's 2-pass softmax falls out of the sharding, with no gather
of the KV cache.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ------------------------------------------------------------------------ rope
def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: [..., S, H, hd] (hd even), positions: [S] or [B, S] int."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq      # [..., S, half]
    ang = ang[..., None, :]                                    # broadcast H
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1.astype(x.dtype), y2.astype(x.dtype)], axis=-1)


FULL_WINDOW = 1 << 30     # "window" value meaning full attention


# ------------------------------------------------------------------------ mask
def _mask(qpos, kpos, *, causal: bool, window, prefix_len: int):
    """True where q may attend k. qpos [qc], kpos [kc] absolute positions.
    `window` may be a TRACED int scalar (per-layer dynamic sliding window);
    pass FULL_WINDOW for full attention."""
    q = qpos[:, None]
    k = kpos[None, :]
    if causal:
        m = k <= q
        if prefix_len:
            m = m | (k < prefix_len)          # prefix-LM: prefix always visible
    else:
        m = jnp.ones((qpos.shape[0], kpos.shape[0]), jnp.bool_)
    if window is not None:
        m = m & (k > q - window)
    return m


# ------------------------------------------------- flash attention (train/prefill)
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window=None,
                    prefix_len: int = 0, q_chunk: int = 512,
                    kv_chunk: int = 512,
                    softmax_scale: float | None = None) -> jax.Array:
    """q: [B, S, H, hd], k/v: [B, S, KV, hd] with H = KV * G. Returns [B, S, H, hd].

    fp32 softmax state; O(q_chunk · kv_chunk) live logits. `window` may be a
    traced scalar (FULL_WINDOW = no windowing). Always called under jit.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(hd)
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, S)
    nq, nk = S // q_chunk, S // kv_chunk
    assert S % q_chunk == 0 and S % kv_chunk == 0, (S, q_chunk, kv_chunk)

    qr = q.reshape(B, nq, q_chunk, KV, G, hd)
    kr = k.reshape(B, nk, kv_chunk, KV, hd)
    vr = v.reshape(B, nk, kv_chunk, KV, hd)

    def one_q_chunk(qi):
        qc = qr[:, qi]                                   # [B, qc, KV, G, hd]
        qpos = qi * q_chunk + jnp.arange(q_chunk)

        @functools.partial(jax.checkpoint,
                           policy=jax.checkpoint_policies.nothing_saveable)
        def kv_step(carry, ki):
            m_run, l_run, acc = carry
            kc = kr[:, ki]                               # [B, kc, KV, hd]
            vc = vr[:, ki]
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            logits = jnp.einsum("bqkgd,bskd->bkgqs", qc, kc,
                                preferred_element_type=jnp.float32) * scale
            msk = _mask(qpos, kpos, causal=causal, window=window,
                        prefix_len=prefix_len)           # [qc, kc]
            logits = jnp.where(msk[None, None, None], logits, NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(logits, axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p, vc.astype(jnp.float32))
            acc = acc * corr[..., None] + pv
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, KV, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_chunk, hd), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                          jnp.arange(nk))
        out = acc / jnp.maximum(l_f[..., None], 1e-30)   # [B, KV, G, qc, hd]
        return jnp.transpose(out, (0, 3, 1, 2, 4))       # [B, qc, KV, G, hd]

    one_q_chunk = jax.checkpoint(
        one_q_chunk, policy=jax.checkpoint_policies.nothing_saveable)
    out = jax.lax.map(one_q_chunk, jnp.arange(nq))       # [nq, B, qc, KV, G, hd]
    out = jnp.transpose(out, (1, 0, 2, 3, 4, 5)).reshape(B, S, H, hd)
    return out.astype(q.dtype)


# --------------------------------------------------------------- decode (1 token)
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cur_index: jax.Array, *, window=None,
                     softmax_scale: float | None = None,
                     k_scale: jax.Array | None = None,
                     v_scale: jax.Array | None = None) -> jax.Array:
    """q: [B, 1, H, hd]; caches: [B, S, KV, hd]; cur_index: scalar int —
    the position being written/read this step (attends to [0, cur_index]).

    int8 caches: pass per-(position, head) `k_scale`/`v_scale` [B, S, KV];
    the dequantization FOLDS into the logits (×k_scale after the dot) and
    the PV contraction (×v_scale into p before the dot), so the cache is
    only ever read as int8 — the decode bandwidth roofline halves vs bf16.

    Reductions run over the cache's S axis; if S is sharded, XLA lowers them
    to partial max/sum + all-reduce (flash-decoding on the mesh).
    """
    B, _, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(hd)
    qh = q.reshape(B, KV, G, hd)

    if k_scale is not None:
        # quantize q per (b, kv, g) so the QK dot is s8×s8→s32 — the cache
        # is never widened; dequant is two rank-3 scalings.
        qs = jnp.maximum(jnp.max(jnp.abs(qh.astype(jnp.float32)), -1)
                         / 127.0, 1e-8)                    # [B,KV,G]
        q8 = jnp.clip(jnp.round(qh.astype(jnp.float32) / qs[..., None]),
                      -127, 127).astype(jnp.int8)
        li = jnp.einsum("bkgd,bskd->bkgs", q8, k_cache,
                        preferred_element_type=jnp.int32)
        logits = li.astype(jnp.float32) * qs[..., None] * scale \
            * jnp.transpose(k_scale, (0, 2, 1))[:, :, None, :]
    else:
        logits = jnp.einsum("bkgd,bskd->bkgs", qh, k_cache,
                            preferred_element_type=jnp.float32) * scale

    pos = jnp.arange(S)
    valid = pos <= cur_index
    if window is not None:
        valid = valid & (pos > cur_index - window)
    logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    pn = p / jnp.maximum(l, 1e-30)
    if v_scale is not None:
        # fold v_scale into the probabilities, then quantize THEM so the
        # PV dot is s8×s8→s32 as well.
        pf = pn * jnp.transpose(v_scale, (0, 2, 1))[:, :, None, :]
        ps = jnp.maximum(jnp.max(pf, -1) / 127.0, 1e-12)   # [B,KV,G]
        p8 = jnp.clip(jnp.round(pf / ps[..., None]), -127, 127) \
            .astype(jnp.int8)
        oi = jnp.einsum("bkgs,bskd->bkgd", p8, v_cache,
                        preferred_element_type=jnp.int32)
        out = oi.astype(jnp.float32) * ps[..., None]
    else:
        # p cast to the cache dtype: avoids materializing an fp32 copy of
        # the ENTIRE cache; accumulation stays fp32.
        out = jnp.einsum("bkgs,bskd->bkgd", pn.astype(v_cache.dtype),
                         v_cache, preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# -------------------------------------------------------------------- reference
def reference_attention(q, k, v, *, causal=True, window=None, prefix_len=0,
                        softmax_scale=None):
    """O(S²) oracle for tests."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(hd)
    qr = q.reshape(B, S, KV, G, hd)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qr, k,
                        preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(S)
    msk = _mask(pos, pos, causal=causal, window=window, prefix_len=prefix_len)
    logits = jnp.where(msk[None, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bkgqd", w, v.astype(jnp.float32))
    return jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(B, S, H, hd).astype(q.dtype)
