"""Mamba-2 (SSD, state-space duality — arXiv:2405.21060), chunked.

Train/prefill use the chunked SSD algorithm: within-chunk "attention-like"
term via the segment-sum decay matrix, across-chunk linear recurrence via
lax.scan over chunk states (O(S·Q) compute, O(S/Q) sequential steps, state
[H, P, N] carried in fp32). Decode is the O(1) per-token recurrence over
the same state — this is what makes long_500k tractable for SSM archs.

Block layout follows the reference Mamba2 module: in_proj → (z | xBC | dt),
depthwise causal conv over xBC, SSD, gated RMSNorm, out_proj. n_groups=1.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import nn


class SSMSpec(NamedTuple):
    d_model: int
    d_inner: int       # expand * d_model
    n_heads: int       # d_inner // head_dim
    head_dim: int      # P
    state: int         # N
    conv_width: int


def spec_from_cfg(cfg) -> SSMSpec:
    d_inner = cfg.ssm_expand * cfg.d_model
    return SSMSpec(cfg.d_model, d_inner, d_inner // cfg.ssm_head_dim,
                   cfg.ssm_head_dim, cfg.ssm_state, cfg.conv_width)


# ------------------------------------------------------------------------ init
def mamba2_init(key, s: SSMSpec, *, param_dtype=jnp.float32):
    conv_ch = s.d_inner + 2 * s.state          # x, B, C share the conv
    d_in_proj = 2 * s.d_inner + 2 * s.state + s.n_heads  # z,xBC,dt
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "in_proj": nn.linear_init(k1, s.d_model, d_in_proj, use_bias=False,
                                  param_dtype=param_dtype),
        "conv_w": nn.lecun_normal()(k2, (s.conv_width, conv_ch), param_dtype),
        "conv_b": jnp.zeros((conv_ch,), param_dtype),
        "A_log": jnp.zeros((s.n_heads,), param_dtype),         # A = -exp(A_log)
        "dt_bias": jnp.full((s.n_heads,), math.log(math.e - 1), param_dtype),
        "D": jnp.ones((s.n_heads,), param_dtype),
        "norm": nn.rmsnorm_init(s.d_inner, param_dtype=param_dtype),
        "out_proj": nn.linear_init(k4, s.d_inner, s.d_model, use_bias=False,
                                   param_dtype=param_dtype),
    }


# ------------------------------------------------------------------- SSD core
def _segsum(a: jax.Array) -> jax.Array:
    """a: [..., Q] -> lower-triangular cumulative sums L[i,j] = sum_{j<m<=i} a_m."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(xh: jax.Array, dtA: jax.Array, dtx_scale: jax.Array,
                Bm: jax.Array, Cm: jax.Array, *, chunk: int,
                initial_state: jax.Array | None = None):
    """Chunked SSD scan.

    xh:   [b, S, H, P]   head inputs
    dtA:  [b, S, H]      log-decay per step (dt * A, negative)
    dtx_scale: [b, S, H] input scale (dt)
    Bm,Cm: [b, S, N]     shared across heads (n_groups=1)
    Returns (y [b,S,H,P], final_state [b,H,P,N]).
    """
    b, S, H, P = xh.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    f32 = jnp.float32

    xc = (xh * dtx_scale[..., None]).astype(f32).reshape(b, nc, chunk, H, P)
    Ac = dtA.astype(f32).reshape(b, nc, chunk, H)
    Bc = Bm.astype(f32).reshape(b, nc, chunk, N)
    Cc = Cm.astype(f32).reshape(b, nc, chunk, N)

    A_cum = jnp.cumsum(Ac, axis=2)                       # [b,nc,Q,H]
    # within-chunk (diagonal) term
    L = jnp.exp(_segsum(jnp.moveaxis(Ac, -1, -2)))       # [b,nc,H,Q,Q]
    G = jnp.einsum("bcqn,bcsn->bcqs", Cc, Bc)            # [b,nc,Q,Q]
    y_diag = jnp.einsum("bcqs,bchqs,bcshp->bcqhp", G, L, xc)

    # end-of-chunk states
    decay_states = jnp.exp(A_cum[:, :, -1:, :] - A_cum)  # [b,nc,Q,H]
    states = jnp.einsum("bcsn,bcsh,bcshp->bchpn", Bc, decay_states, xc)
    chunk_decay = jnp.exp(A_cum[:, :, -1, :])            # [b,nc,H]

    # across-chunk recurrence (sequential scan over chunks)
    def step(carry, inp):
        st, dec = inp                                    # [b,H,P,N], [b,H]
        prev = carry
        new = prev * dec[..., None, None] + st
        return new, prev

    init = (jnp.zeros((b, H, P, N), f32) if initial_state is None
            else initial_state.astype(f32))
    final, prev_states = jax.lax.scan(
        step, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)        # [b,nc,H,P,N]

    # cross-chunk (off-diagonal) contribution
    state_decay_out = jnp.exp(A_cum)                     # [b,nc,Q,H]
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", Cc, prev_states,
                       state_decay_out)
    y = (y_diag + y_off).reshape(b, S, H, P)
    return y, final


# ------------------------------------------------------------------ block apply
def _split_proj(s: SSMSpec, zxbcdt: jax.Array):
    z, xBC, dt = jnp.split(
        zxbcdt, [s.d_inner, 2 * s.d_inner + 2 * s.state], axis=-1)
    return z, xBC, dt


def mamba2_train(p, s: SSMSpec, x: jax.Array, *, chunk: int = 256,
                 dtype=jnp.bfloat16, return_state: bool = False):
    """x: [B, S, d_model] -> [B, S, d_model] (full-sequence train/prefill)."""
    B, S, _ = x.shape
    zxbcdt = nn.linear_apply(p["in_proj"], x, dtype=dtype)
    z, xBC, dt = _split_proj(s, zxbcdt)

    # depthwise causal conv over features of xBC
    w = p["conv_w"].astype(jnp.float32)                  # [W, conv_ch]
    xBC32 = xBC.astype(jnp.float32)
    pad = jnp.pad(xBC32, ((0, 0), (s.conv_width - 1, 0), (0, 0)))
    conv = sum(pad[:, i:i + S, :] * w[i] for i in range(s.conv_width))
    xBC = jax.nn.silu(conv + p["conv_b"].astype(jnp.float32))

    xh, Bm, Cm = jnp.split(xBC, [s.d_inner, s.d_inner + s.state], axis=-1)
    xh = xh.reshape(B, S, s.n_heads, s.head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))         # [H]
    dtA = dt * A[None, None, :]                          # [B,S,H]

    y, final = ssd_chunked(xh, dtA, dt, Bm, Cm, chunk=chunk)
    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, S, s.d_inner)
    y = nn.rmsnorm_apply(p["norm"], y * jax.nn.silu(z.astype(jnp.float32)))
    out = nn.linear_apply(p["out_proj"], y.astype(dtype), dtype=dtype)
    if return_state:
        conv_state = xBC32[:, S - (s.conv_width - 1):, :] if S >= s.conv_width - 1 \
            else jnp.pad(xBC32, ((0, 0), (s.conv_width - 1 - S, 0), (0, 0)))
        # NOTE: conv state stores PRE-activation (pre-silu, pre-bias) inputs
        return out.astype(x.dtype), (final, conv_state)
    return out.astype(x.dtype)


def mamba2_decode(p, s: SSMSpec, x: jax.Array, state: jax.Array,
                  conv_state: jax.Array, *, dtype=jnp.bfloat16):
    """One token. x: [B, 1, d_model]; state: [B,H,P,N] fp32;
    conv_state: [B, W-1, conv_ch] fp32 (pre-activation xBC history)."""
    B = x.shape[0]
    zxbcdt = nn.linear_apply(p["in_proj"], x[:, 0, :], dtype=dtype)
    z, xBC_new, dt = _split_proj(s, zxbcdt)

    hist = jnp.concatenate([conv_state,
                            xBC_new.astype(jnp.float32)[:, None, :]], axis=1)
    w = p["conv_w"].astype(jnp.float32)
    conv = jnp.einsum("bwc,wc->bc", hist, w) + p["conv_b"].astype(jnp.float32)
    xBC = jax.nn.silu(conv)
    new_conv_state = hist[:, 1:, :]

    xh, Bm, Cm = jnp.split(xBC, [s.d_inner, s.d_inner + s.state], axis=-1)
    xh = xh.reshape(B, s.n_heads, s.head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dt * A[None, :])                          # [B,H]
    new_state = state * a[..., None, None] + \
        jnp.einsum("bh,bhp,bn->bhpn", dt, xh.astype(jnp.float32), Bm)
    y = jnp.einsum("bn,bhpn->bhp", Cm, new_state)
    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, s.d_inner)
    y = nn.rmsnorm_apply(p["norm"], y * jax.nn.silu(z.astype(jnp.float32)))
    out = nn.linear_apply(p["out_proj"], y.astype(dtype), dtype=dtype)
    return out[:, None, :].astype(x.dtype), new_state, new_conv_state


# ---------------------------------------------------------------------- oracle
def ssd_reference(xh, dtA, dtx_scale, Bm, Cm, initial_state=None):
    """O(S) sequential recurrence oracle for tests (exact SSD semantics)."""
    b, S, H, P = xh.shape
    N = Bm.shape[-1]
    st = jnp.zeros((b, H, P, N), jnp.float32) if initial_state is None \
        else initial_state.astype(jnp.float32)

    def step(st, t):
        a = jnp.exp(dtA[:, t, :]).astype(jnp.float32)            # [b,H]
        xt = (xh[:, t] * dtx_scale[:, t, :, None]).astype(jnp.float32)
        st = st * a[..., None, None] + jnp.einsum("bhp,bn->bhpn", xt, Bm[:, t])
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, t], st)
        return st, y

    st, ys = jax.lax.scan(step, st, jnp.arange(S))
    return jnp.moveaxis(ys, 0, 1), st                            # [b,S,H,P]
