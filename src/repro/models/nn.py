"""Minimal functional NN substrate (no flax offline).

Every module is a pair of pure functions:
  init(rng, ...) -> params (a pytree of jnp arrays)
  apply(params, x, ...) -> y

Params are plain dicts so they shard/pjit/compress trivially. Initializers
match common practice (trunc-normal fan-in for projections, ones/zeros for
norms). dtype policy: `param_dtype` for storage, `dtype` for compute.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

Params = Any  # pytree of arrays
Initializer = Callable[[jax.Array, Sequence[int], Any], jax.Array]


# ---------------------------------------------------------------- initializers
def trunc_normal(stddev: float = 0.02) -> Initializer:
    def init(key, shape, dtype):
        return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
                * stddev).astype(dtype)
    return init


def lecun_normal() -> Initializer:
    def init(key, shape, dtype):
        fan_in = shape[0] if len(shape) >= 1 else 1
        if len(shape) == 4:  # HWIO conv
            fan_in = shape[0] * shape[1] * shape[2]
        stddev = 1.0 / math.sqrt(max(1, fan_in))
        return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
                * stddev).astype(dtype)
    return init


def zeros_init() -> Initializer:
    return lambda key, shape, dtype: jnp.zeros(shape, dtype)


def ones_init() -> Initializer:
    return lambda key, shape, dtype: jnp.ones(shape, dtype)


# ---------------------------------------------------------------------- linear
def linear_init(key, in_dim: int, out_dim: int, *, use_bias: bool = True,
                param_dtype=jnp.float32, init: Initializer | None = None) -> Params:
    init = init or lecun_normal()
    p = {"kernel": init(key, (in_dim, out_dim), param_dtype)}
    if use_bias:
        p["bias"] = jnp.zeros((out_dim,), param_dtype)
    return p


def linear_apply(p: Params, x: jax.Array, *, dtype=None) -> jax.Array:
    k = p["kernel"]
    if dtype is not None:
        k = k.astype(dtype)
        x = x.astype(dtype)
    y = x @ k
    if "bias" in p:
        y = y + p["bias"].astype(y.dtype)
    return y


# ------------------------------------------------------------------- embedding
def embedding_init(key, vocab: int, dim: int, *, param_dtype=jnp.float32) -> Params:
    return {"embedding": trunc_normal(1.0 / math.sqrt(dim))(key, (vocab, dim), param_dtype)}


def embedding_apply(p: Params, ids: jax.Array, *, dtype=None) -> jax.Array:
    emb = p["embedding"]
    if dtype is not None:
        emb = emb.astype(dtype)
    return jnp.take(emb, ids, axis=0)


def embedding_attend(p: Params, x: jax.Array, *, dtype=None) -> jax.Array:
    """Tied decode head: logits = x @ E^T."""
    emb = p["embedding"]
    if dtype is not None:
        emb = emb.astype(dtype)
        x = x.astype(dtype)
    return x @ emb.T


# ----------------------------------------------------------------------- norms
def rmsnorm_init(dim: int, *, param_dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((dim,), param_dtype)}


def rmsnorm_apply(p: Params, x: jax.Array, *, eps: float = 1e-6,
                  upcast: bool = True) -> jax.Array:
    orig_dtype = x.dtype
    if upcast:
        x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * p["scale"].astype(x.dtype)
    return y.astype(orig_dtype)


def layernorm_init(dim: int, *, param_dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((dim,), param_dtype),
            "bias": jnp.zeros((dim,), param_dtype)}


def layernorm_apply(p: Params, x: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    orig_dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(x.dtype) + p["bias"].astype(x.dtype)
    return y.astype(orig_dtype)


# ------------------------------------------------------------------------ conv
def conv2d_init(key, in_ch: int, out_ch: int, kernel: int, *,
                param_dtype=jnp.float32) -> Params:
    return {"kernel": lecun_normal()(key, (kernel, kernel, in_ch, out_ch), param_dtype),
            "bias": jnp.zeros((out_ch,), param_dtype)}


def conv2d_apply(p: Params, x: jax.Array, *, stride: int = 1,
                 padding: str = "SAME") -> jax.Array:
    y = jax.lax.conv_general_dilated(
        x, p["kernel"].astype(x.dtype), window_strides=(stride, stride),
        padding=padding, dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["bias"].astype(y.dtype)


def max_pool(x: jax.Array, window: int = 2, stride: int = 2) -> jax.Array:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, window, window, 1), (1, stride, stride, 1),
        "VALID")


# ------------------------------------------------------------------------ lstm
def lstm_cell_init(key, in_dim: int, hidden: int, *, param_dtype=jnp.float32) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "wi": lecun_normal()(k1, (in_dim, 4 * hidden), param_dtype),
        "wh": lecun_normal()(k2, (hidden, 4 * hidden), param_dtype),
        "bias": jnp.zeros((4 * hidden,), param_dtype),
    }


def lstm_cell_apply(p: Params, carry, x: jax.Array):
    h, c = carry
    gates = x @ p["wi"].astype(x.dtype) + h @ p["wh"].astype(x.dtype) \
        + p["bias"].astype(x.dtype)
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return (h, c), h


def lstm_layer_apply(p: Params, xs: jax.Array) -> jax.Array:
    """xs: [B, T, D] -> hs [B, T, H] via lax.scan over time."""
    B = xs.shape[0]
    H = p["wh"].shape[0]
    init = (jnp.zeros((B, H), xs.dtype), jnp.zeros((B, H), xs.dtype))

    def step(carry, x_t):
        return lstm_cell_apply(p, carry, x_t)

    _, hs = jax.lax.scan(step, init, jnp.swapaxes(xs, 0, 1))
    return jnp.swapaxes(hs, 0, 1)


# ------------------------------------------------------------------ activation
def gelu(x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x, approximate=True)


def silu(x: jax.Array) -> jax.Array:
    return jax.nn.silu(x)


ACT = {"gelu": gelu, "silu": silu, "relu": jax.nn.relu, "tanh": jnp.tanh}


# ------------------------------------------------------------------- utilities
def count_params(params: Params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def param_bytes(params: Params) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(params))


def tree_zeros_like(params: Params, dtype=None) -> Params:
    return jax.tree.map(
        lambda x: jnp.zeros(x.shape, dtype or x.dtype), params)


@dataclasses.dataclass(frozen=True)
class DTypePolicy:
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16

    @staticmethod
    def small() -> "DTypePolicy":
        return DTypePolicy(jnp.float32, jnp.float32)

    @staticmethod
    def large() -> "DTypePolicy":
        return DTypePolicy(jnp.float32, jnp.bfloat16)
