"""Mixture-of-Experts layer: top-k router + capacity-buffer grouped GEMM.

Dispatch is the sort → position-in-group → scatter-to-[E, C, d] formulation:
the grouped matmuls are plain einsums over the expert axis and the FLOPs
are active-only (E·C·d_ff with C ≈ top_k·T/E·capacity_factor) — no
[T, E, C] one-hot tensor and no dense all-experts compute. Over-capacity
tokens are dropped (standard Switch-style; the router's softmax weights of
dropped slots are lost, tested to be < a few % at cf=1.25).

Distributed path (`shard_tokens_axes`): the dispatch's argsort/scatter are
token-order-dependent, so under plain GSPMD they replicate the token
stream (observed +70 GiB/device on qwen3 train_4k). The sharded path runs
the WHOLE layer inside a fully-manual shard_map:

  tokens   sharded over the batch axes (dispatch is shard-local),
  experts  TP-in-expert: d_ff sharded over `model`, d_model over the FSDP
           axis — weights are explicitly all-gathered over FSDP (ZeRO-3)
           and the partial outputs psum'd over `model`.

(A partial-manual shard_map variant tickles an XLA-CPU AllReducePromotion
crash — "Invalid binary instruction opcode copy" — hence fully manual.)
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import nn


def moe_init(key, d_model: int, d_ff: int, n_experts: int, *,
             param_dtype=jnp.float32):
    kr, kg, ku, kd = jax.random.split(key, 4)
    lim = 1.0 / math.sqrt(d_model)
    init = nn.trunc_normal(lim)
    return {
        "router": nn.linear_init(kr, d_model, n_experts, use_bias=False,
                                 param_dtype=param_dtype),
        "w_gate": init(kg, (n_experts, d_model, d_ff), param_dtype),
        "w_up": init(ku, (n_experts, d_model, d_ff), param_dtype),
        "w_down": nn.trunc_normal(1.0 / math.sqrt(d_ff))(
            kd, (n_experts, d_ff, d_model), param_dtype),
    }


def _dispatch_compute(xf, router_k, w_gate, w_up, w_down, *, n_experts: int,
                      top_k: int, capacity_factor: float, dtype):
    """Core token-choice dispatch + grouped GEMMs on FULL-d weights.
    xf: [T, d]; w_gate/w_up: [E, d, f(maybe a TP slice)]; w_down: [E, f, d].
    Returns [T, d] (a PARTIAL sum if f is a TP slice — caller psums)."""
    T, d = xf.shape

    # ---- router (fp32 for numerics)
    logits = (xf.astype(jnp.float32) @ router_k.astype(jnp.float32))
    gate_vals, sel = jax.lax.top_k(logits, top_k)                 # [T, k]
    probs = jax.nn.softmax(gate_vals, axis=-1)                    # renormalized

    # ---- flatten slots: slot j = token t, choice i  (token-major)
    TK = T * top_k
    flat_eid = sel.reshape(TK)
    flat_w = probs.reshape(TK)

    # ---- sort slots by expert, position within expert group
    sort_idx = jnp.argsort(flat_eid)
    sorted_eid = flat_eid[sort_idx]
    counts = jnp.bincount(flat_eid, length=n_experts)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(TK) - starts[sorted_eid]

    cap = int(math.ceil(top_k * T / n_experts * capacity_factor))
    cap = max(8, ((cap + 7) // 8) * 8)                            # lane align
    keep = pos < cap
    safe_pos = jnp.where(keep, pos, 0)

    # ---- scatter tokens into the [E, C, d] buffer
    tok_of_slot = sort_idx // top_k
    gathered = xf[tok_of_slot].astype(dtype)
    buf = jnp.zeros((n_experts, cap, d), dtype)
    buf = buf.at[sorted_eid, safe_pos].add(
        jnp.where(keep[:, None], gathered, 0))

    # ---- grouped GEMMs (f may be a TP slice)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(dtype))) \
        * jnp.einsum("ecd,edf->ecf", buf, w_up.astype(dtype))
    y_buf = jnp.einsum("ecf,efd->ecd", h, w_down.astype(dtype))

    # ---- gather back to slots, weight, combine over top_k
    y_sorted = y_buf[sorted_eid, safe_pos]
    y_sorted = jnp.where(keep[:, None], y_sorted, 0)
    inv = jnp.argsort(sort_idx)
    # y_sorted[inv] is slot-(token-major-)ordered; flat_w already is.
    y_slots = y_sorted[inv] * flat_w[:, None].astype(dtype)
    return y_slots.reshape(T, top_k, d).sum(axis=1)


def moe_apply(p, x: jax.Array, *, n_experts: int, top_k: int,
              capacity_factor: float = 1.25, dtype=jnp.bfloat16,
              shard_tokens_axes: tuple | None = None,
              fsdp_axis: str = "data",
              expert_tp_axis: str = "model") -> jax.Array:
    """x: [B, S, d] -> [B, S, d]. See module docstring for the sharded path.

    Sharded-path weight layout (must match repro.dist.sharding rules):
      router  [d, E]     replicated
      w_gate  [E, d, f]  P(None, fsdp, tp)
      w_up    [E, d, f]  P(None, fsdp, tp)
      w_down  [E, f, d]  P(None, tp, fsdp)
    """
    B, S, d = x.shape
    if not shard_tokens_axes:
        xf = x.reshape(B * S, d)
        y = _dispatch_compute(xf, p["router"]["kernel"], p["w_gate"],
                              p["w_up"], p["w_down"], n_experts=n_experts,
                              top_k=top_k, capacity_factor=capacity_factor,
                              dtype=dtype)
        return y.reshape(B, S, d).astype(x.dtype)

    from jax.sharding import PartitionSpec as P
    baxes = tuple(shard_tokens_axes)
    manual = set(baxes) | {fsdp_axis, expert_tp_axis}

    def local(router_k, wg, wu, wd, x_loc):
        # explicit ZeRO-3 gather of the FSDP (d_model) slices
        wg = jax.lax.all_gather(wg, fsdp_axis, axis=1, tiled=True)
        wu = jax.lax.all_gather(wu, fsdp_axis, axis=1, tiled=True)
        wd = jax.lax.all_gather(wd, fsdp_axis, axis=2, tiled=True)
        b_loc = x_loc.shape[0]
        xf = x_loc.reshape(b_loc * S, d)
        # token-chunked dispatch: the [E, C, d] capacity buffer and the
        # [T·k, d] gathered-slot tensors scale 1/n_chunks (2.7 GiB → 0.7
        # on qwen3); chunks are checkpointed so backward recomputes them.
        T_loc = xf.shape[0]
        nch = 1
        for cand in (4, 2, 1):
            if T_loc % cand == 0 and T_loc // cand >= 1024:
                nch = cand
                break

        @jax.checkpoint
        def one(xc):
            return _dispatch_compute(xc, router_k, wg, wu, wd,
                                     n_experts=n_experts, top_k=top_k,
                                     capacity_factor=capacity_factor,
                                     dtype=dtype)

        if nch > 1:
            y = jax.lax.map(one, xf.reshape(nch, T_loc // nch, d))
            y = y.reshape(T_loc, d)
        else:
            y = one(xf)
        # f was a TP slice → partial sums over the expert TP axis
        y = jax.lax.psum(y, expert_tp_axis)
        return y.reshape(b_loc, S, d)

    f = jax.shard_map(
        local,
        in_specs=(P(), P(None, fsdp_axis, expert_tp_axis),
                  P(None, fsdp_axis, expert_tp_axis),
                  P(None, expert_tp_axis, fsdp_axis),
                  P(baxes, None, None)),
        out_specs=P(baxes, None, None),
        axis_names=manual,
        check_vma=False,
    )
    return f(p["router"]["kernel"], p["w_gate"], p["w_up"], p["w_down"],
             x).astype(x.dtype)


def moe_aux_loss(p, x: jax.Array, *, n_experts: int, top_k: int) -> jax.Array:
    """Switch-style load-balancing auxiliary loss (mean_prob · mean_assign · E)."""
    B, S, d = x.shape
    xf = x.reshape(B * S, d)
    logits = nn.linear_apply(p["router"], xf, dtype=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                       # [T, E]
    _, sel = jax.lax.top_k(logits, top_k)
    assign = jnp.zeros_like(probs).at[
        jnp.arange(xf.shape[0])[:, None], sel].set(1.0)
    return n_experts * jnp.mean(jnp.mean(probs, 0) * jnp.mean(assign, 0))
