"""Unified decoder/encoder stack covering all assigned families.

One scanned block structure per family (uniform pytree across layers →
jax.lax.scan over stacked [L, ...] params keeps the HLO O(1) in depth):

  dense  : attn + (gated|gelu) MLP            (gemma3 / starcoder2 / stablelm …)
  moe    : attn + MoE                          (grok-1, qwen3-moe)
  ssm    : mamba2 block only                   (mamba2-780m)
  hybrid : parallel attn+SSM heads, then MLP   (hymba)
  audio  : non-causal attn + MLP encoder       (hubert)
  vlm    : prefix-LM decoder over [patches; text]  (paligemma)

Mixed local/global attention (gemma3's 5:1, hymba's 3 full layers) is
handled INSIDE the scan with a per-layer dynamic window scalar — sliding-
window layers get w, full layers get S+1 — so the layer pytree stays
uniform. Blocks are wrapped in jax.checkpoint (remat) for training.

Losses use a sequence-chunked cross-entropy so the [B, S, vocab] logits
tensor is never materialized (vocab up to 262k).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import mamba2 as m2
from repro.models import moe as moe_lib
from repro.models import nn
from repro.models.attention import decode_attention, flash_attention, rope

FULL_WINDOW = 1 << 30


def explicit_gather(x, spec):
    """All-gather a sharded leaf to full size via an EXPLICIT collective in
    a shard_map manual region. Unlike with_sharding_constraint, SPMD cannot
    hoist/commute this out of a layer scan (it satisfies a replication
    constraint by replicating the whole [L, ...] stack instead — observed
    +15 GiB). The transpose is a reduce-scatter, so grads land back on the
    FSDP shards automatically."""
    from jax.sharding import PartitionSpec as P
    entries = [(d, e) for d, e in enumerate(spec) if e is not None]
    if not entries:
        return x
    axes = []
    for _, e in entries:
        axes += list(e) if isinstance(e, (tuple, list)) else [e]

    def fn(loc):
        for dim, e in entries:
            for ax in (e if isinstance(e, (tuple, list)) else (e,)):
                loc = jax.lax.all_gather(loc, ax, axis=dim, tiled=True)
        return loc

    return jax.shard_map(fn, in_specs=(spec,),
                         out_specs=P(*[None] * x.ndim),
                         axis_names=set(axes), check_vma=False)(x)


def _norm_init(cfg, d):
    return nn.rmsnorm_init(d) if cfg.norm_type == "rms" else nn.layernorm_init(d)


def _norm_apply(cfg, p, x):
    return nn.rmsnorm_apply(p, x) if cfg.norm_type == "rms" \
        else nn.layernorm_apply(p, x)


# ------------------------------------------------------------------ block init
def block_init(key, cfg: ArchConfig, *, param_dtype=jnp.float32):
    d = cfg.d_model
    hd = cfg.head_dim_
    ks = list(jax.random.split(key, 12))
    p: dict[str, Any] = {}
    if cfg.has_attention:
        p["attn_norm"] = _norm_init(cfg, d)
        p["wq"] = nn.linear_init(ks[0], d, cfg.n_heads * hd, use_bias=False,
                                 param_dtype=param_dtype)
        p["wk"] = nn.linear_init(ks[1], d, cfg.n_kv_heads * hd, use_bias=False,
                                 param_dtype=param_dtype)
        p["wv"] = nn.linear_init(ks[2], d, cfg.n_kv_heads * hd, use_bias=False,
                                 param_dtype=param_dtype)
        p["wo"] = nn.linear_init(ks[3], cfg.n_heads * hd, d, use_bias=False,
                                 param_dtype=param_dtype)
    if cfg.has_ssm:
        p["ssm_norm"] = _norm_init(cfg, d)
        p["ssm"] = m2.mamba2_init(ks[4], m2.spec_from_cfg(cfg),
                                  param_dtype=param_dtype)
    if cfg.n_experts:
        p["ffn_norm"] = _norm_init(cfg, d)
        p["moe"] = moe_lib.moe_init(ks[5], d, cfg.d_ff, cfg.n_experts,
                                    param_dtype=param_dtype)
    elif cfg.mlp_type == "gated":
        p["ffn_norm"] = _norm_init(cfg, d)
        p["w_gate"] = nn.linear_init(ks[6], d, cfg.d_ff, use_bias=False,
                                     param_dtype=param_dtype)
        p["w_up"] = nn.linear_init(ks[7], d, cfg.d_ff, use_bias=False,
                                   param_dtype=param_dtype)
        p["w_down"] = nn.linear_init(ks[8], cfg.d_ff, d, use_bias=False,
                                     param_dtype=param_dtype)
    elif cfg.mlp_type == "gelu":
        p["ffn_norm"] = _norm_init(cfg, d)
        p["fc1"] = nn.linear_init(ks[6], d, cfg.d_ff, param_dtype=param_dtype)
        p["fc2"] = nn.linear_init(ks[7], cfg.d_ff, d, param_dtype=param_dtype)
    return p


# ------------------------------------------------------------- block sub-parts
def _attn_full(cfg, p, x, window, *, positions, dtype, prefix_len=0):
    """Full-sequence attention (train/prefill). Returns (out, (k, v))."""
    B, S, _ = x.shape
    hd = cfg.head_dim_
    h = _norm_apply(cfg, p["attn_norm"], x)
    q = nn.linear_apply(p["wq"], h, dtype=dtype).reshape(B, S, cfg.n_heads, hd)
    k = nn.linear_apply(p["wk"], h, dtype=dtype).reshape(B, S, cfg.n_kv_heads, hd)
    v = nn.linear_apply(p["wv"], h, dtype=dtype).reshape(B, S, cfg.n_kv_heads, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    o = flash_attention(q, k, v, causal=cfg.causal, window=window,
                        prefix_len=prefix_len)
    out = nn.linear_apply(p["wo"], o.reshape(B, S, -1), dtype=dtype)
    return out, (k, v)


def _quantize_kv(x):
    """Per-(position, kv-head) symmetric int8: x [B, S, KV, hd] →
    (int8 codes, fp32 scales [B, S, KV])."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    codes = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                     -127, 127).astype(jnp.int8)
    return codes, scale


def _attn_decode(cfg, p, x, cache, cur_index, window, *, dtype):
    """One-token attention against the cache. Returns (out, new_cache_kv).
    Supports bf16 caches and int8 caches (with per-position scales — the
    dequant folds into the logits/PV einsums, so the HBM stream stays
    int8: halves the decode's memory-bandwidth roofline term)."""
    B = x.shape[0]
    hd = cfg.head_dim_
    int8_cache = "k_scale" in cache
    h = _norm_apply(cfg, p["attn_norm"], x)
    q = nn.linear_apply(p["wq"], h, dtype=dtype).reshape(B, 1, cfg.n_heads, hd)
    k = nn.linear_apply(p["wk"], h, dtype=dtype).reshape(B, 1, cfg.n_kv_heads, hd)
    v = nn.linear_apply(p["wv"], h, dtype=dtype).reshape(B, 1, cfg.n_kv_heads, hd)
    pos = cur_index[None]                                  # [1]
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)
    kc, vc = cache["k"], cache["v"]
    new_cache = {}
    if int8_cache:
        k8, ks = _quantize_kv(k)
        v8, vs = _quantize_kv(v)
        upd = lambda c, u: jax.lax.dynamic_update_slice_in_dim(
            c, u.astype(c.dtype), cur_index, 1)
        kc, vc = upd(kc, k8), upd(vc, v8)
        kss = upd(cache["k_scale"], ks)
        vss = upd(cache["v_scale"], vs)
        new_cache.update(k_scale=kss, v_scale=vss)
        o = decode_attention(q, kc, vc, cur_index, window=window,
                             k_scale=kss, v_scale=vss)
    else:
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype),
                                                 cur_index, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype),
                                                 cur_index, 1)
        o = decode_attention(q, kc, vc, cur_index, window=window)
    out = nn.linear_apply(p["wo"], o.reshape(B, 1, -1), dtype=dtype)
    new_cache.update(k=kc, v=vc)
    return out, new_cache


def _ffn(cfg, p, x, *, dtype, moe_axes=None):
    if cfg.n_experts:
        h = _norm_apply(cfg, p["ffn_norm"], x)
        return moe_lib.moe_apply(p["moe"], h, n_experts=cfg.n_experts,
                                 top_k=cfg.moe_top_k,
                                 capacity_factor=cfg.capacity_factor,
                                 dtype=dtype, shard_tokens_axes=moe_axes)
    if cfg.mlp_type == "gated":
        h = _norm_apply(cfg, p["ffn_norm"], x)
        g = jax.nn.silu(nn.linear_apply(p["w_gate"], h, dtype=dtype))
        u = nn.linear_apply(p["w_up"], h, dtype=dtype)
        return nn.linear_apply(p["w_down"], g * u, dtype=dtype)
    if cfg.mlp_type == "gelu":
        h = _norm_apply(cfg, p["ffn_norm"], x)
        h = nn.gelu(nn.linear_apply(p["fc1"], h, dtype=dtype))
        return nn.linear_apply(p["fc2"], h, dtype=dtype)
    return None


# ----------------------------------------------------------------- block apply
def block_train(cfg: ArchConfig, p, x, window, *, positions, dtype,
                prefix_len=0, collect_cache: bool = False, moe_axes=None):
    """Full-sequence block. Returns (x, cache_layer|None)."""
    cache = {}
    if cfg.parallel_ssm:                      # hymba: attn ‖ ssm on same input
        a_out, kv = _attn_full(cfg, p, x, window, positions=positions,
                               dtype=dtype, prefix_len=prefix_len)
        s_in = _norm_apply(cfg, p["ssm_norm"], x)
        if collect_cache:
            s_out, (st, cv) = m2.mamba2_train(p["ssm"], m2.spec_from_cfg(cfg),
                                              s_in, dtype=dtype,
                                              return_state=True)
            cache.update(k=kv[0], v=kv[1], ssm=st, conv=cv)
        else:
            s_out = m2.mamba2_train(p["ssm"], m2.spec_from_cfg(cfg), s_in,
                                    dtype=dtype)
        x = x + 0.5 * (a_out + s_out)
        if collect_cache and cfg.has_attention:
            pass
    elif cfg.has_ssm:                         # mamba2: SSM is the mixer
        s_in = _norm_apply(cfg, p["ssm_norm"], x)
        if collect_cache:
            s_out, (st, cv) = m2.mamba2_train(p["ssm"], m2.spec_from_cfg(cfg),
                                              s_in, dtype=dtype,
                                              return_state=True)
            cache.update(ssm=st, conv=cv)
        else:
            s_out = m2.mamba2_train(p["ssm"], m2.spec_from_cfg(cfg), s_in,
                                    dtype=dtype)
        x = x + s_out
    else:
        a_out, kv = _attn_full(cfg, p, x, window, positions=positions,
                               dtype=dtype, prefix_len=prefix_len)
        x = x + a_out
        if collect_cache:
            cache.update(k=kv[0], v=kv[1])

    f = _ffn(cfg, p, x, dtype=dtype, moe_axes=moe_axes)
    if f is not None:
        x = x + f
    return x, (cache if collect_cache else None)


def block_decode(cfg: ArchConfig, p, x, cache, cur_index, window, *, dtype,
                 moe_axes=None):
    """One-token block vs cache. Returns (x, new_cache)."""
    new_cache = dict(cache)
    if cfg.parallel_ssm:
        a_out, kv_cache = _attn_decode(cfg, p, x, cache, cur_index, window,
                                       dtype=dtype)
        s_in = _norm_apply(cfg, p["ssm_norm"], x)
        s_out, st, cv = m2.mamba2_decode(p["ssm"], m2.spec_from_cfg(cfg),
                                         s_in, cache["ssm"], cache["conv"],
                                         dtype=dtype)
        x = x + 0.5 * (a_out + s_out)
        new_cache.update(ssm=st, conv=cv, **kv_cache)
    elif cfg.has_ssm:
        s_in = _norm_apply(cfg, p["ssm_norm"], x)
        s_out, st, cv = m2.mamba2_decode(p["ssm"], m2.spec_from_cfg(cfg),
                                         s_in, cache["ssm"], cache["conv"],
                                         dtype=dtype)
        x = x + s_out
        new_cache.update(ssm=st, conv=cv)
    else:
        a_out, kv_cache = _attn_decode(cfg, p, x, cache, cur_index, window,
                                       dtype=dtype)
        x = x + a_out
        new_cache.update(**kv_cache)
    f = _ffn(cfg, p, x, dtype=dtype, moe_axes=moe_axes)
    if f is not None:
        x = x + f
    return x, new_cache


# -------------------------------------------------------------------- LM model
@dataclasses.dataclass(frozen=True)
class LM:
    """Facade: init / loss / prefill / decode for one ArchConfig."""
    cfg: ArchConfig
    dtype: Any = jnp.bfloat16       # compute dtype
    param_dtype: Any = jnp.float32  # storage dtype (bf16 for the full archs)
    remat: bool = True
    remat_policy: str = "nothing"   # nothing | dots
    use_scan: bool = True           # scan over layers (False: unrolled —
                                    # used by the dry-run cost extrapolation)
    batch_axes: tuple | None = None  # mesh axes for the activation batch dim;
                                     # set by the launcher (e.g. ("data",) or
                                     # ("pod","data")) to pin the residual-
                                     # stream layout under GSPMD. None = no
                                     # constraint (single-device tests).
    moe_dispatch_axes: tuple | None = None  # shard-local MoE dispatch over
                                     # these (token/batch) mesh axes.
    zero3_layer: bool = False        # streamed ZeRO-3: fully gather each
                                     # layer's weights INSIDE the scan body
                                     # (one layer in flight), for the pure-DP
                                     # layout where batch covers the mesh.
    layer_param_specs: Any = None    # pytree of PartitionSpec for ONE layer
                                     # (stack spec minus the L dim); required
                                     # when zero3_layer is set.
    kv_dtype: str = "compute"        # "compute" (bf16/f32) | "int8" — int8
                                     # stores per-(position, kv-head) scales
                                     # alongside and halves the decode HBM
                                     # roofline term (§Perf bonus cell).
    act_seq_axis: str | None = None  # Megatron-style sequence parallelism:
                                     # shard the residual stream's S dim over
                                     # this mesh axis (attention gathers K/V
                                     # around it). None = S replicated.

    def _constrain(self, x):
        """Residual stream: [B(batch_axes), S(act_seq_axis), d]. Without this
        GSPMD may drop the batch sharding and emit full-batch partial-sum
        all-reduces (observed: 3.4 GiB fp32 ARs on stablelm train_4k)."""
        if self.batch_axes is None:
            return x
        from jax.sharding import PartitionSpec as P
        rest = [None] * (x.ndim - 1)
        if self.act_seq_axis is not None and x.ndim >= 3 and x.shape[1] > 1:
            rest[0] = self.act_seq_axis
        spec = P(tuple(self.batch_axes), *rest)
        return jax.lax.with_sharding_constraint(x, spec)

    # ------------------------------------------------------------------ init
    def init(self, key) -> dict:
        cfg = self.cfg
        pd = self.param_dtype
        k_emb, k_layers, k_head, k_fe = jax.random.split(key, 4)
        params: dict[str, Any] = {}
        if cfg.frontend == "tokens" or cfg.frontend == "patches":
            params["embed"] = nn.embedding_init(k_emb, cfg.vocab, cfg.d_model,
                                                param_dtype=pd)
        if cfg.frontend == "frames":
            params["frontend"] = nn.linear_init(k_fe, cfg.frame_dim,
                                                cfg.d_model, param_dtype=pd)
            params["head"] = nn.linear_init(k_head, cfg.d_model, cfg.vocab,
                                            param_dtype=pd)
        if cfg.frontend == "patches":
            params["patch_proj"] = nn.linear_init(k_fe, cfg.patch_dim,
                                                  cfg.d_model, param_dtype=pd)
        layer_keys = jax.random.split(k_layers, cfg.n_layers)
        params["layers"] = jax.vmap(
            lambda k: block_init(k, cfg, param_dtype=pd))(layer_keys)
        params["final_norm"] = _norm_init(cfg, cfg.d_model)
        return params

    # ------------------------------------------------------------- internals
    def _windows(self, S: int) -> jax.Array:
        cfg = self.cfg
        return jnp.asarray([cfg.window if k == "sw" else FULL_WINDOW
                            for k in cfg.layer_kinds()], jnp.int32)

    def _stack(self, params, x, *, positions, prefix_len=0,
               collect_cache=False):
        cfg = self.cfg
        windows = self._windows(x.shape[1])

        def body(h, xs):
            lp, w = xs
            if self.zero3_layer:
                from jax.sharding import PartitionSpec as P
                lp = jax.tree.map(
                    explicit_gather, lp, self.layer_param_specs,
                    is_leaf=lambda s: isinstance(s, P))
            h = self._constrain(h)
            out, cache = block_train(cfg, lp, h, w, positions=positions,
                                     dtype=self.dtype, prefix_len=prefix_len,
                                     collect_cache=collect_cache,
                                     moe_axes=self.moe_dispatch_axes)
            return self._constrain(out), cache

        f = body
        if self.remat:
            policy = (jax.checkpoint_policies.nothing_saveable
                      if self.remat_policy == "nothing"
                      else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
            f = jax.checkpoint(body, policy=policy)
        if self.use_scan:
            x, caches = jax.lax.scan(f, x, (params["layers"], windows))
        else:  # unrolled (dry-run per-layer cost extrapolation)
            cache_list = []
            for i in range(cfg.n_layers):
                lp = jax.tree.map(lambda a: a[i], params["layers"])
                x, c = f(x, (lp, windows[i]))
                cache_list.append(c)
            caches = (jax.tree.map(lambda *xs: jnp.stack(xs), *cache_list)
                      if collect_cache else None)
        x = _norm_apply(cfg, params["final_norm"], x)
        return x, caches

    def _embed_inputs(self, params, batch):
        """Returns (x [B,S,d], positions [S], prefix_len, label_offset)."""
        cfg = self.cfg
        if cfg.frontend == "frames":
            x = nn.linear_apply(params["frontend"], batch["frames"],
                                dtype=self.dtype)
            S = x.shape[1]
            return self._constrain(x), jnp.arange(S), 0
        if cfg.frontend == "patches":
            pe = nn.linear_apply(params["patch_proj"], batch["patches"],
                                 dtype=self.dtype)
            te = nn.embedding_apply(params["embed"], batch["tokens"],
                                    dtype=self.dtype)
            x = jnp.concatenate([pe, te], axis=1)
            S = x.shape[1]
            return self._constrain(x), jnp.arange(S), cfg.n_patches
        x = nn.embedding_apply(params["embed"], batch["tokens"],
                               dtype=self.dtype)
        return self._constrain(x), jnp.arange(x.shape[1]), 0

    # ------------------------------------------------------------------ loss
    def loss(self, params, batch) -> jax.Array:
        cfg = self.cfg
        x, positions, prefix = self._embed_inputs(params, batch)
        h, _ = self._stack(params, x, positions=positions, prefix_len=prefix)
        labels = batch["labels"]
        if cfg.frontend == "frames":       # per-frame classification (stub)
            logits = nn.linear_apply(params["head"], h, dtype=jnp.float32)
            return _ce(logits, labels)
        if cfg.frontend == "patches":      # loss on text positions only
            h = h[:, cfg.n_patches:, :]
        # next-token LM loss, chunked over sequence
        return chunked_ce_loss(h, params["embed"]["embedding"], labels)

    # --------------------------------------------------------------- prefill
    def prefill(self, params, batch):
        cfg = self.cfg
        x, positions, prefix = self._embed_inputs(params, batch)
        h, caches = self._stack(params, x, positions=positions,
                                prefix_len=prefix, collect_cache=True)
        last = h[:, -1, :]
        logits = self._head(params, last[:, None, :])
        return logits, caches

    def _head(self, params, h):
        cfg = self.cfg
        if cfg.frontend == "frames":
            return nn.linear_apply(params["head"], h, dtype=jnp.float32)
        emb = params["embed"]["embedding"].astype(self.dtype)
        return (h.astype(self.dtype) @ emb.T).astype(jnp.float32)

    # ---------------------------------------------------------------- decode
    def decode_step(self, params, cache, token, cur_index):
        """token: [B, 1] int32; cur_index: scalar int32 (position to write).
        Returns (logits [B, 1, vocab], new_cache)."""
        cfg = self.cfg
        x = self._constrain(
            nn.embedding_apply(params["embed"], token, dtype=self.dtype))
        windows = self._windows(1)

        def body(h, xs):
            lp, cl, w = xs
            out, new_cl = block_decode(cfg, lp, h, cl, cur_index, w,
                                       dtype=self.dtype,
                                       moe_axes=self.moe_dispatch_axes)
            return self._constrain(out), new_cl

        if self.use_scan:
            x, new_cache = jax.lax.scan(body, x,
                                        (params["layers"], cache, windows))
        else:
            cls = []
            for i in range(cfg.n_layers):
                lp = jax.tree.map(lambda a: a[i], params["layers"])
                cl = jax.tree.map(lambda a: a[i], cache)
                x, ncl = body(x, (lp, cl, windows[i]))
                cls.append(ncl)
            new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *cls)
        x = _norm_apply(cfg, params["final_norm"], x)
        return self._head(params, x), new_cache

    # ------------------------------------------------------------- cache init
    def init_cache(self, B: int, S: int, *, dtype=None):
        """Zeroed cache pytree with leading layer dim [L, ...]."""
        cfg = self.cfg
        dt = dtype or self.dtype
        L = cfg.n_layers
        c: dict[str, Any] = {}
        if cfg.has_attention:
            hd = cfg.head_dim_
            if self.kv_dtype == "int8":
                c["k"] = jnp.zeros((L, B, S, cfg.n_kv_heads, hd), jnp.int8)
                c["v"] = jnp.zeros((L, B, S, cfg.n_kv_heads, hd), jnp.int8)
                c["k_scale"] = jnp.zeros((L, B, S, cfg.n_kv_heads),
                                         jnp.float32)
                c["v_scale"] = jnp.zeros((L, B, S, cfg.n_kv_heads),
                                         jnp.float32)
            else:
                c["k"] = jnp.zeros((L, B, S, cfg.n_kv_heads, hd), dt)
                c["v"] = jnp.zeros((L, B, S, cfg.n_kv_heads, hd), dt)
        if cfg.has_ssm:
            s = m2.spec_from_cfg(cfg)
            c["ssm"] = jnp.zeros((L, B, s.n_heads, s.head_dim, s.state),
                                 jnp.float32)
            c["conv"] = jnp.zeros((L, B, s.conv_width - 1,
                                   s.d_inner + 2 * s.state), jnp.float32)
        return c

    def cache_specs(self, B: int, S: int):
        return jax.eval_shape(lambda: self.init_cache(B, S))


# ----------------------------------------------------------------------- losses
def _ce(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def chunked_ce_loss(h: jax.Array, embedding: jax.Array, labels: jax.Array,
                    *, chunk: int = 512) -> jax.Array:
    """CE(h @ E^T, labels) without materializing [B, S, V]."""
    B, S, d = h.shape
    chunk = min(chunk, S)
    while S % chunk:        # e.g. vlm text length 3840 → chunk 256
        chunk //= 2
    nc = S // chunk
    hc = h.reshape(B, nc, chunk, d)
    lc = labels.reshape(B, nc, chunk)
    emb = embedding.astype(jnp.bfloat16)

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def one(ci):
        logits = (hc[:, ci].astype(jnp.bfloat16) @ emb.T).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, lc[:, ci][..., None], axis=-1)[..., 0]
        return -jnp.sum(ll)

    total = jax.lax.map(one, jnp.arange(nc))
    return jnp.sum(total) / (B * S)
