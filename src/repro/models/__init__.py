from repro.models import nn, small

__all__ = ["nn", "small"]
