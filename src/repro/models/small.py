"""The paper's experiment models (Sec 4.1) + a fast MLP for unit tests.

  - 4-layer CNN for FMNIST  (inspired by Li et al. 2020, as cited)
  - VGG11s (slim VGG11, Sattler et al.-style) for CIFAR-10
  - 2-layer 128-unit LSTM for Speech Commands

All follow the nn.py functional protocol: init(rng) -> params,
apply(params, batch_inputs) -> logits, plus `make_task` adapters producing
core.simulator.TrainTask objects over the synthetic stand-in datasets.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import nn


# ------------------------------------------------------------------- CNN (FMNIST)
def cnn_init(key, *, num_classes: int = 10, in_ch: int = 1):
    ks = jax.random.split(key, 4)
    return {
        "conv1": nn.conv2d_init(ks[0], in_ch, 32, 5),
        "conv2": nn.conv2d_init(ks[1], 32, 64, 5),
        "fc1": nn.linear_init(ks[2], 64 * 7 * 7, 512),
        "fc2": nn.linear_init(ks[3], 512, num_classes),
    }


def cnn_apply(p, image):
    x = image
    x = jax.nn.relu(nn.conv2d_apply(p["conv1"], x))
    x = nn.max_pool(x)
    x = jax.nn.relu(nn.conv2d_apply(p["conv2"], x))
    x = nn.max_pool(x)
    x = x.reshape((x.shape[0], -1))
    x = jax.nn.relu(nn.linear_apply(p["fc1"], x))
    return nn.linear_apply(p["fc2"], x)


# --------------------------------------------------------------- VGG11s (CIFAR-10)
_VGG11S_PLAN = [(32, 1), ("M",), (64, 1), ("M",), (128, 2), ("M",),
                (256, 2), ("M",)]  # slim: half the channels of VGG11


def vgg11s_init(key, *, num_classes: int = 10, in_ch: int = 3):
    params, ch = {}, in_ch
    i = 0
    for item in _VGG11S_PLAN:
        if item[0] == "M":
            continue
        out_ch, reps = item
        for _ in range(reps):
            key, sub = jax.random.split(key)
            params[f"conv{i}"] = nn.conv2d_init(sub, ch, out_ch, 3)
            ch = out_ch
            i += 1
    key, k1, k2 = jax.random.split(key, 3)
    params["fc1"] = nn.linear_init(k1, 256 * 2 * 2, 256)
    params["fc2"] = nn.linear_init(k2, 256, num_classes)
    return params


def vgg11s_apply(p, image):
    x = image
    i = 0
    for item in _VGG11S_PLAN:
        if item[0] == "M":
            x = nn.max_pool(x)
            continue
        _, reps = item
        for _ in range(reps):
            x = jax.nn.relu(nn.conv2d_apply(p[f"conv{i}"], x))
            i += 1
    x = x.reshape((x.shape[0], -1))
    x = jax.nn.relu(nn.linear_apply(p["fc1"], x))
    return nn.linear_apply(p["fc2"], x)


# ------------------------------------------------------------------- LSTM (SC)
def lstm_init(key, *, features: int = 40, hidden: int = 128,
              num_classes: int = 10):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "lstm1": nn.lstm_cell_init(k1, features, hidden),
        "lstm2": nn.lstm_cell_init(k2, hidden, hidden),
        "head": nn.linear_init(k3, hidden, num_classes),
    }


def lstm_apply(p, frames):
    h = nn.lstm_layer_apply(p["lstm1"], frames)
    h = nn.lstm_layer_apply(p["lstm2"], h)
    return nn.linear_apply(p["head"], h[:, -1, :])


# --------------------------------------------------------------------- fast MLP
def mlp_init(key, *, in_dim: int = 784, hidden: int = 128,
             num_classes: int = 10):
    k1, k2 = jax.random.split(key)
    return {"fc1": nn.linear_init(k1, in_dim, hidden),
            "fc2": nn.linear_init(k2, hidden, num_classes)}


def mlp_apply(p, image):
    x = image.reshape((image.shape[0], -1))
    x = jax.nn.relu(nn.linear_apply(p["fc1"], x))
    return nn.linear_apply(p["fc2"], x)


# ------------------------------------------------------------------ task adapters
def softmax_xent(logits, labels):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def accuracy(logits, labels):
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))


def make_task(name: str, *, num_samples: int = 4000, test_samples: int = 1000,
              batch_size: int = 64, seed: int = 0, noise: float | None = None):
    """Build a core.simulator.TrainTask for one of the paper's tasks
    (synthetic data stand-ins; see repro.data.synthetic)."""
    from repro.core.simulator import TrainTask
    from repro.data.synthetic import (SyntheticClassification, SyntheticSpeech)

    kw = {} if noise is None else {"noise": noise}
    if name == "mlp_micro":
        # tiny MLP (8x8 inputs, 32 hidden, d ~= 2.4k): per-step compute is
        # negligible, so runs are dominated by harness overhead — the
        # workload simulator-engine benchmarks use to measure event
        # throughput rather than model FLOPs.
        ds = SyntheticClassification(shape=(8, 8, 1), num_samples=num_samples,
                                     seed=seed, sample_seed=seed, **kw)
        test = SyntheticClassification(shape=(8, 8, 1),
                                       num_samples=test_samples, seed=seed,
                                       sample_seed=seed + 999, **kw)
        def init(rng):
            return mlp_init(rng, in_dim=64, hidden=32)
        apply, key_in = mlp_apply, "image"
    elif name in ("cnn_fmnist", "mlp_fmnist"):
        ds = SyntheticClassification(shape=(28, 28, 1), num_samples=num_samples,
                                     seed=seed, sample_seed=seed, **kw)
        test = SyntheticClassification(shape=(28, 28, 1),
                                       num_samples=test_samples, seed=seed,
                                       sample_seed=seed + 999, **kw)
        init, apply, key_in = (
            (cnn_init, cnn_apply, "image") if name == "cnn_fmnist"
            else (mlp_init, mlp_apply, "image"))
    elif name == "vgg11s_cifar10":
        ds = SyntheticClassification(shape=(32, 32, 3), num_samples=num_samples,
                                     seed=seed, sample_seed=seed, **kw)
        test = SyntheticClassification(shape=(32, 32, 3),
                                       num_samples=test_samples, seed=seed,
                                       sample_seed=seed + 999, **kw)
        init, apply, key_in = vgg11s_init, vgg11s_apply, "image"
    elif name == "lstm_sc":
        ds = SyntheticSpeech(num_samples=num_samples, seed=seed,
                             sample_seed=seed, **kw)
        test = SyntheticSpeech(num_samples=test_samples, seed=seed,
                               sample_seed=seed + 999, **kw)
        init, apply, key_in = lstm_init, lstm_apply, "frames"
    else:
        raise ValueError(f"unknown task {name}")

    test_batch = test.batch(jnp.arange(len(test)))

    def loss_fn(params, batch):
        return softmax_xent(apply(params, batch[key_in]), batch["label"])

    def acc_fn(params, batch):
        return accuracy(apply(params, batch[key_in]), batch["label"])

    return TrainTask(name=name, init_fn=lambda rng: init(rng),
                     loss_fn=loss_fn, acc_fn=acc_fn, dataset=ds,
                     test_batch=test_batch, batch_size=batch_size)
