"""FedLuck reproduction: joint local-updating + gradient-compression AFL.

Importing any `repro.*` module first installs the jax back-compat shims
(`repro._compat`) so the sharding-era API surface the code is written
against (`AxisType`, `make_mesh(axis_types=)`, `set_mesh`, `shard_map`)
exists on the pinned jax 0.4.37 toolchain.
"""
from repro import _compat

_compat.install()
