"""Structured event tracer over the simulator's *simulated* clock.

Events are plain records (span / instant) on named tracks — one track per
device plus dedicated server and controller tracks — appended to a host
list in emission order. The simulator emits only at engine-shared seams
(heap-pop sites, `_schedule_upload`, `_maybe_replan`, aggregation, eval),
so the batched and sequential engines produce the *same* event list on the
same run; that list equality is itself a correctness gate
(tests/test_simulator_batched.py).

Timestamps are simulated seconds (floats from the event heap). No wall
clock, no RNG: tracing can never perturb a run's results.

`NullTracer` is the zero-cost default path's measurement twin: the
simulator guards every call site with `tracer is not None`, so the default
(`tracer=None`) pays one predicate per site; passing a `NullTracer`
exercises every site with no-op method calls — which is what the CI
overhead gate times against the default.
"""
from __future__ import annotations

import dataclasses


SERVER_TRACK = "server"
CONTROLLER_TRACK = "controller"


def device_track(device_id: int) -> str:
    return f"device/{device_id}"


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One trace record. `ph` follows the Chrome trace phase convention:
    "X" = complete span (ts + dur), "i" = instant. `ts`/`dur` are simulated
    seconds; the Perfetto exporter converts to microseconds."""
    track: str
    name: str
    ph: str                   # "X" | "i"
    ts: float                 # simulated seconds
    dur: float = 0.0          # span length (ph == "X")
    args: tuple = ()          # sorted (key, value) pairs — hashable, ordered

    def arg(self, key: str, default=None):
        for k, v in self.args:
            if k == key:
                return v
        return default


def _args(kw: dict) -> tuple:
    return tuple(sorted(kw.items()))


class Tracer:
    """Recording tracer: appends TraceEvents to `self.events`."""

    enabled = True

    def __init__(self):
        self.events: list[TraceEvent] = []

    # ------------------------------------------------------------------ emit
    def span(self, track: str, name: str, t0: float, t1: float, **kw) -> None:
        """Complete span [t0, t1] on `track` (simulated seconds)."""
        self.events.append(TraceEvent(track, name, "X", float(t0),
                                      float(t1) - float(t0), _args(kw)))

    def instant(self, track: str, name: str, t: float, **kw) -> None:
        self.events.append(TraceEvent(track, name, "i", float(t),
                                      0.0, _args(kw)))

    # ----------------------------------------------------------------- query
    def __len__(self) -> int:
        return len(self.events)

    def by_name(self, name: str) -> list[TraceEvent]:
        return [e for e in self.events if e.name == name]

    def tracks(self) -> list[str]:
        seen: dict[str, None] = {}
        for e in self.events:
            seen.setdefault(e.track)
        return list(seen)

    def clear(self) -> None:
        self.events.clear()


class NullTracer(Tracer):
    """Every emission is a no-op; used to measure call-site overhead."""

    enabled = False

    def span(self, track, name, t0, t1, **kw) -> None:
        pass

    def instant(self, track, name, t, **kw) -> None:
        pass


NULL_TRACER = NullTracer()
