"""Observability layer: structured event tracing, streaming metrics, and
profiling hooks for the AFL stack.

Four small pieces, composable and individually optional:

  trace      `Tracer` — per-device spans and instant events recorded in
             *simulated* time (local-round compute, upload attempt/retry/
             loss, crash/recovery windows, sanitizer rejections, controller
             re-plans, eval rounds). `NullTracer` keeps every call site a
             no-op so the hot path stays zero-cost when tracing is off.
  perfetto   `PerfettoExporter` — Chrome-trace/Perfetto JSON (one track per
             device plus server/controller tracks), loadable in
             ui.perfetto.dev. `validate_chrome_trace` is the schema gate
             (required keys: ph, ts, pid, tid, name) used by the tests and
             the CI obs-smoke job.
  metrics    `MetricsRegistry` — counters, gauges, and fixed-bucket
             histograms (pure host-side Python, no wall clock or RNG in
             hot paths): staleness per eval window, wire-bit breakdowns
             (payload / header / retransmission), batched-engine bucket
             occupancy and recompiles, channel/sanitizer/controller totals.
  profiling  `PhaseTimers` (perf_counter wall-clock phase accumulators for
             heap-drain / bucket dispatch / host aggregation) and
             `annotate()` — an optional `jax.profiler` trace-annotation
             context around the pod-sync / compact-topk / fused-momentum
             dispatches, enabled via `set_profiling(True)` or
             REPRO_PROFILE=1.
  log        stdout-safe status lines: progress text goes to stderr (and a
             `--quiet` flag silences it), so benchmark JSON on stdout is
             never interleaved with progress prints.

The simulator (`repro.core.simulator.AFLSimulator(tracer=..., metrics=...)`)
injects all instrumentation at the engine-shared seams, so the batched and
sequential engines emit *identical* traces and metric totals on identical
runs — tested in tests/test_simulator_batched.py.
"""
from repro.obs import log
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               STALENESS_BUCKETS)
from repro.obs.perfetto import (PerfettoExporter, validate_chrome_trace,
                                validate_metrics_json)
from repro.obs.profiling import (PhaseTimers, annotate, profiling_enabled,
                                 set_profiling)
from repro.obs.trace import (NULL_TRACER, NullTracer, TraceEvent, Tracer,
                             CONTROLLER_TRACK, SERVER_TRACK, device_track)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "STALENESS_BUCKETS",
    "PerfettoExporter", "validate_chrome_trace", "validate_metrics_json",
    "PhaseTimers", "annotate", "profiling_enabled", "set_profiling",
    "NULL_TRACER", "NullTracer", "TraceEvent", "Tracer",
    "CONTROLLER_TRACK", "SERVER_TRACK", "device_track", "log",
]
