"""CLI schema validator for exported observability artifacts.

  PYTHONPATH=src python -m repro.obs.check /tmp/trace.json /tmp/metrics.json

Validates the Perfetto/Chrome trace (required keys ph/ts/pid/tid/name,
labelled tracks) and the metrics JSON (section shape, histogram count
invariants) with the same functions the unit tests use, and prints a
one-line summary per file. Exits non-zero on the first violation — the CI
obs-smoke job runs this over the sim_bench --trace-out/--metrics-out
artifacts.
"""
from __future__ import annotations

import argparse
import sys

from repro.obs.perfetto import validate_chrome_trace, validate_metrics_json


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="Chrome-trace/Perfetto JSON path")
    ap.add_argument("metrics", nargs="?", default="",
                    help="metrics JSON path (optional)")
    ap.add_argument("--min-device-tracks", type=int, default=1,
                    help="require at least this many per-device tracks")
    args = ap.parse_args(argv)

    try:
        info = validate_chrome_trace(args.trace)
    except (ValueError, KeyError, OSError) as e:
        print(f"[obs.check] FAIL {args.trace}: {e}", file=sys.stderr)
        return 1
    n_dev = len(info["device_tracks"])
    if n_dev < args.min_device_tracks:
        print(f"[obs.check] FAIL {args.trace}: only {n_dev} device tracks "
              f"(need >= {args.min_device_tracks})", file=sys.stderr)
        return 1
    print(f"[obs.check] OK {args.trace}: {info['events']} events, "
          f"{len(info['tracks'])} tracks ({n_dev} devices)")

    if args.metrics:
        try:
            validate_metrics_json(args.metrics)
        except (ValueError, KeyError, OSError) as e:
            print(f"[obs.check] FAIL {args.metrics}: {e}", file=sys.stderr)
            return 1
        print(f"[obs.check] OK {args.metrics}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
