"""Stdout-safe status logging for launchers and benchmarks.

Progress text goes to stderr so machine-readable JSON on stdout is never
interleaved with human status lines; `set_quiet(True)` (the launchers'
`--quiet` flag) silences status output entirely. Result payloads that ARE
the program's output (final JSON) should keep using plain print/stdout.
"""
from __future__ import annotations

import sys

_QUIET = False


def set_quiet(quiet: bool) -> None:
    global _QUIET
    _QUIET = bool(quiet)


def quiet() -> bool:
    return _QUIET


def status(msg: str) -> None:
    """One progress line to stderr (suppressed under --quiet)."""
    if not _QUIET:
        print(msg, file=sys.stderr, flush=True)
