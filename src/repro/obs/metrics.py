"""Streaming metrics: counters, gauges, fixed-bucket histograms.

Pure host-side Python with no wall clock and no RNG in the hot path —
observing a value is a dict lookup plus a bisect into *fixed* bucket
bounds, so metric updates can never perturb a simulation and both engines
produce identical registries on identical runs.

Naming convention used by the simulator:

  sim.*      engine-agnostic simulation metrics (cycles, staleness,
             wire-bit breakdown) — identical across engines
  faults.*   fault-counter totals mirrored from
             `AFLSimulator.fault_counters()` at run end, so exported JSON
             totals match `History.counters` exactly
  engine.*   execution-engine internals (bucket occupancy, chunk shapes,
             recompiles) — legitimately engine-specific
  time.*     wall-clock phase timers (profiling.PhaseTimers) — host noise,
             never compared across runs

`snapshot()` returns a plain JSON-ready dict; the cross-engine equality
test compares snapshots with the engine./time. sections stripped
(`snapshot(engine_agnostic=True)`).
"""
from __future__ import annotations

import bisect
import json


# staleness τ is a small integer; pow2-ish edges keep tails visible
STALENESS_BUCKETS = (0, 1, 2, 4, 8, 16, 32, 64)


class Counter:
    """Monotonic float total."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class Gauge:
    """Last-write-wins scalar."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed-bucket histogram. Bucket i counts values v with
    bounds[i-1] < v <= bounds[i]; the final bucket is the +inf overflow,
    so `counts` has len(bounds) + 1 entries and `sum(counts) == count`."""

    __slots__ = ("bounds", "counts", "count", "total")

    def __init__(self, bounds):
        b = tuple(float(x) for x in bounds)
        if not b or any(b[i] >= b[i + 1] for i in range(len(b) - 1)):
            raise ValueError(f"bucket bounds must be strictly increasing, "
                             f"got {bounds}")
        self.bounds = b
        self.counts = [0] * (len(b) + 1)
        self.count = 0
        self.total = 0.0

    def observe(self, v: float) -> None:
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.count += 1
        self.total += v

    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0


class MetricsRegistry:
    def __init__(self):
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    # ------------------------------------------------------------ get-or-make
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge()
        return g

    def histogram(self, name: str, bounds=None) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            if bounds is None:
                raise ValueError(f"histogram {name!r} needs bucket bounds on "
                                 f"first use")
            h = self._histograms[name] = Histogram(bounds)
        return h

    # ---------------------------------------------------------------- totals
    def merge_totals(self, prefix: str, totals: dict) -> None:
        """Overwrite `<prefix><key>` counters with absolute totals — used to
        mirror `fault_counters()` so exported totals match History.counters
        exactly instead of re-deriving them incrementally."""
        for k, v in totals.items():
            self.counter(prefix + k).value = float(v)

    # -------------------------------------------------------------- snapshot
    def snapshot(self, *, engine_agnostic: bool = False) -> dict:
        def keep(name: str) -> bool:
            return not engine_agnostic or not (
                name.startswith("engine.") or name.startswith("time."))

        return {
            "counters": {k: c.value for k, c in sorted(self._counters.items())
                         if keep(k)},
            "gauges": {k: g.value for k, g in sorted(self._gauges.items())
                       if keep(k)},
            "histograms": {
                k: {"bounds": list(h.bounds), "counts": list(h.counts),
                    "count": h.count, "sum": h.total}
                for k, h in sorted(self._histograms.items()) if keep(k)},
        }

    def to_json(self, path: str, *, extra: dict | None = None) -> dict:
        doc = {"schema": "repro.obs.metrics/v1", **(extra or {}),
               **self.snapshot()}
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
        return doc
