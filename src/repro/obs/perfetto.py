"""Chrome-trace / Perfetto JSON export and schema validation.

`PerfettoExporter` turns a `Tracer`'s event list into the Chrome trace
event format (the JSON flavour ui.perfetto.dev and chrome://tracing both
load): one process ("afl-sim"), one thread track per simulator track —
server, controller, then each device — with thread_name metadata so the
UI shows readable labels. Simulated seconds become microseconds.

`validate_chrome_trace` is the schema gate the unit tests and the CI
obs-smoke job share: every event must carry the required keys
(ph, ts, pid, tid, name), spans need a non-negative dur, and track
metadata must resolve every tid.
"""
from __future__ import annotations

import json

from repro.obs.trace import (CONTROLLER_TRACK, SERVER_TRACK, Tracer,
                             device_track)

PID = 1
_US = 1e6                       # simulated seconds -> trace microseconds
REQUIRED_KEYS = ("ph", "ts", "pid", "tid", "name")
# fixed tids so traces from different runs line up: server, controller,
# then devices at a stable offset
_SERVER_TID = 1
_CONTROLLER_TID = 2
_DEVICE_TID0 = 10


class PerfettoExporter:
    """Stateless exporter: `export(tracer, path)` or `to_chrome(tracer)`."""

    def __init__(self, *, process_name: str = "afl-sim"):
        self.process_name = process_name

    # ------------------------------------------------------------- track ids
    @staticmethod
    def _tid(track: str) -> int:
        if track == SERVER_TRACK:
            return _SERVER_TID
        if track == CONTROLLER_TRACK:
            return _CONTROLLER_TID
        if track.startswith("device/"):
            return _DEVICE_TID0 + int(track.split("/", 1)[1])
        # unknown tracks get a stable hash-free fallback lane
        return _DEVICE_TID0 - 1

    @staticmethod
    def _label(track: str) -> str:
        if track.startswith("device/"):
            return f"device {track.split('/', 1)[1]}"
        return track

    # ----------------------------------------------------------------- build
    def to_chrome(self, tracer: Tracer) -> dict:
        events: list[dict] = [{
            "ph": "M", "ts": 0, "pid": PID, "tid": 0,
            "name": "process_name", "args": {"name": self.process_name},
        }]
        tracks: dict[str, int] = {}
        for e in tracer.events:
            tracks.setdefault(e.track, self._tid(e.track))
        # stable presentation order: server, controller, devices ascending
        for track, tid in sorted(tracks.items(), key=lambda kv: kv[1]):
            events.append({"ph": "M", "ts": 0, "pid": PID, "tid": tid,
                           "name": "thread_name",
                           "args": {"name": self._label(track)}})
            events.append({"ph": "M", "ts": 0, "pid": PID, "tid": tid,
                           "name": "thread_sort_index",
                           "args": {"sort_index": tid}})
        for e in tracer.events:
            rec = {"ph": e.ph, "ts": e.ts * _US, "pid": PID,
                   "tid": tracks[e.track], "name": e.name, "cat": "sim"}
            if e.ph == "X":
                rec["dur"] = e.dur * _US
            else:
                rec["s"] = "t"          # thread-scoped instant
            if e.args:
                rec["args"] = dict(e.args)
            events.append(rec)
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": {"producer": "repro.obs",
                              "clock": "simulated seconds x 1e6"}}

    def export(self, tracer: Tracer, path: str) -> dict:
        doc = self.to_chrome(tracer)
        with open(path, "w") as f:
            json.dump(doc, f, indent=None, separators=(",", ":"))
            f.write("\n")
        return doc


# ------------------------------------------------------------------ validate
def validate_chrome_trace(doc: dict | str) -> dict:
    """Validate a Chrome-trace JSON document (or a path to one).

    Returns {"events": n, "tracks": {tid: label}, "device_tracks": [...]}.
    Raises ValueError on any schema violation — the unit tests and the CI
    obs-smoke job both call this.
    """
    if isinstance(doc, str):
        with open(doc) as f:
            doc = json.load(f)
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("not a Chrome trace: missing traceEvents")
    events = doc["traceEvents"]
    if not isinstance(events, list) or not events:
        raise ValueError("traceEvents must be a non-empty list")
    labels: dict[int, str] = {}
    n_real = 0
    for i, e in enumerate(events):
        for key in REQUIRED_KEYS:
            if key not in e:
                raise ValueError(f"event {i} missing required key {key!r}: "
                                 f"{e}")
        if e["ph"] == "M":
            if e["name"] == "thread_name":
                labels[e["tid"]] = e["args"]["name"]
            continue
        n_real += 1
        if e["ph"] not in ("X", "i", "C", "B", "E"):
            raise ValueError(f"event {i} has unknown phase {e['ph']!r}")
        if not isinstance(e["ts"], (int, float)) or e["ts"] < 0:
            raise ValueError(f"event {i} has bad ts {e['ts']!r}")
        if e["ph"] == "X" and e.get("dur", 0) < 0:
            raise ValueError(f"event {i} has negative dur")
        if e["tid"] not in labels:
            raise ValueError(f"event {i} tid {e['tid']} has no thread_name "
                             f"metadata")
    if n_real == 0:
        raise ValueError("trace has only metadata events")
    return {"events": n_real, "tracks": labels,
            "device_tracks": sorted(v for v in labels.values()
                                    if v.startswith("device "))}


def validate_metrics_json(doc: dict | str) -> dict:
    """Validate a MetricsRegistry JSON export (or a path to one).
    Returns the parsed document; raises ValueError on schema violations."""
    if isinstance(doc, str):
        with open(doc) as f:
            doc = json.load(f)
    if not isinstance(doc, dict):
        raise ValueError("metrics JSON must be an object")

    def check_section(sec: dict) -> None:
        for key in ("counters", "gauges", "histograms"):
            if key not in sec or not isinstance(sec[key], dict):
                raise ValueError(f"metrics section missing {key!r}")
        for name, h in sec["histograms"].items():
            if sum(h["counts"]) != h["count"]:
                raise ValueError(f"histogram {name!r}: counts do not sum to "
                                 f"count")
            if len(h["counts"]) != len(h["bounds"]) + 1:
                raise ValueError(f"histogram {name!r}: needs len(bounds)+1 "
                                 f"buckets")

    if "counters" in doc:
        check_section(doc)
    else:                       # multi-engine export: one section per engine
        subs = [v for v in doc.values()
                if isinstance(v, dict) and "counters" in v]
        if not subs:
            raise ValueError("no metrics sections found")
        for sub in subs:
            check_section(sub)
    return doc
