"""Profiling hooks: wall-clock phase timers and jax.profiler annotations.

`PhaseTimers` accumulates `time.perf_counter` wall-clock totals per named
phase (heap-drain, bucket dispatch, host aggregation, eval). perf_counter
is monotonic — immune to clock adjustments — and the timers live entirely
host-side, outside jit, so they never touch traced code.

`annotate(name)` wraps a host-side dispatch in a `jax.profiler`
TraceAnnotation when profiling is switched on (`set_profiling(True)` or
REPRO_PROFILE=1 in the environment), so `jax.profiler.trace()` captures
show the pod-sync / compact-topk / fused-momentum dispatches as named
regions. When profiling is off it returns a shared null context — one
module-level predicate per call, no allocation.
"""
from __future__ import annotations

import contextlib
import os
import time

_PROFILE = os.environ.get("REPRO_PROFILE", "") not in ("", "0", "false")
_NULL_CTX = contextlib.nullcontext()


def set_profiling(on: bool) -> None:
    """Globally enable/disable jax.profiler trace annotations."""
    global _PROFILE
    _PROFILE = bool(on)


def profiling_enabled() -> bool:
    return _PROFILE


def annotate(name: str):
    """Context manager: a jax.profiler TraceAnnotation named `name` when
    profiling is enabled, else a shared no-op context."""
    if not _PROFILE:
        return _NULL_CTX
    try:
        import jax
        return jax.profiler.TraceAnnotation(name)
    except Exception:               # profiler unavailable on this backend
        return _NULL_CTX


class PhaseTimers:
    """Named wall-clock accumulators: `with timers.phase("drain"): ...`."""

    def __init__(self):
        self.totals: dict[str, float] = {}
        self.calls: dict[str, int] = {}

    @contextlib.contextmanager
    def phase(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            dt = time.perf_counter() - t0
            self.totals[name] = self.totals.get(name, 0.0) + dt
            self.calls[name] = self.calls.get(name, 0) + 1

    def add(self, name: str, seconds: float) -> None:
        """Manual accumulation for phases that cannot use a with-block."""
        self.totals[name] = self.totals.get(name, 0.0) + float(seconds)
        self.calls[name] = self.calls.get(name, 0) + 1

    def snapshot(self) -> dict:
        return {name: {"seconds": round(self.totals[name], 6),
                       "calls": self.calls[name]}
                for name in sorted(self.totals)}

    def export_to(self, metrics) -> None:
        """Mirror totals into a MetricsRegistry under the time.* namespace
        (wall-clock: excluded from cross-engine equality by convention)."""
        for name, total in self.totals.items():
            metrics.counter(f"time.{name}_s").value = total
            metrics.counter(f"time.{name}_calls").value = \
                float(self.calls[name])
