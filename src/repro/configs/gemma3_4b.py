"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144.
5:1 local(sliding-window):global attention, 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=10240, vocab=262144,
    attn_pattern=("sw", "sw", "sw", "sw", "sw", "full"), window=1024,
    rope_theta=1_000_000.0, mlp_type="gated",
    # long_500k runs: 5/6 of layers are window-bounded; global-layer KV is
    # sequence-sharded over the mesh (see DESIGN.md §5).
    source="hf:google/gemma-3-1b-pt; unverified",
)
