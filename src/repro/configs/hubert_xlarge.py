"""hubert-xlarge [audio]: 48L d_model=1280 16H (MHA) d_ff=5120 vocab=504.
Encoder-only (no decode shapes). Modality frontend = STUB: input_specs()
provides precomputed frame embeddings [B, S, frame_dim].
[arXiv:2106.07447; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, head_dim=80,
    d_ff=5120, vocab=504,
    attn_pattern=("full",), causal=False, mlp_type="gelu", norm_type="layer",
    frontend="frames", frame_dim=512, tie_embeddings=False,
    skip_shapes=("decode_32k", "long_500k"),  # encoder-only (DESIGN.md §5)
    source="arXiv:2106.07447; unverified",
)
