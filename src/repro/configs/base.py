"""ArchConfig: one dataclass describing every assigned architecture, the
input-shape grid (train_4k / prefill_32k / decode_32k / long_500k), and the
reduced smoke variants. configs/<id>.py instantiate it.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

# The four assigned LM shapes: (seq_len, global_batch, kind)
SHAPES: dict[str, dict] = {
    "train_4k":    {"seq": 4096,    "batch": 256, "kind": "train"},
    "prefill_32k": {"seq": 32768,   "batch": 32,  "kind": "prefill"},
    "decode_32k":  {"seq": 32768,   "batch": 128, "kind": "decode"},
    "long_500k":   {"seq": 524288,  "batch": 1,   "kind": "decode"},
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | hybrid | audio | ssm | vlm
    n_layers: int
    d_model: int
    n_heads: int                    # 0 for attention-free (mamba2)
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    # attention pattern: cycled over layers; "sw" = sliding window, "full"
    attn_pattern: tuple = ("full",)
    window: int = 1024
    causal: bool = True
    rope_theta: float = 10_000.0
    mlp_type: str = "gated"         # gated (SiLU) | gelu | none
    norm_type: str = "rms"          # rms | layer
    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba2 / hymba)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    parallel_ssm: bool = False      # hymba: attention + SSM heads in parallel
    # IO frontend
    frontend: str = "tokens"        # tokens | frames | patches
    frame_dim: int = 512            # audio stub: precomputed frame embedding dim
    n_patches: int = 256            # vlm stub: number of image patches
    patch_dim: int = 1152           # vlm stub: precomputed patch embedding dim
    tie_embeddings: bool = True
    # which assigned shapes this arch skips (with the reason in DESIGN.md)
    skip_shapes: tuple = ()
    # provenance
    source: str = ""

    # ------------------------------------------------------------- derived
    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def ssm_heads(self) -> int:
        return (self.ssm_expand * self.d_model) // self.ssm_head_dim

    @property
    def has_attention(self) -> bool:
        return self.n_heads > 0

    @property
    def has_ssm(self) -> bool:
        return self.ssm_state > 0

    def layer_kinds(self) -> list[str]:
        """Per-layer attention kind ('full'|'sw'|'ssm') cycling the pattern."""
        pat = self.attn_pattern
        return [pat[i % len(pat)] for i in range(self.n_layers)]

    def is_global_flags(self) -> jnp.ndarray:
        """float32[L]: 1.0 where the layer uses FULL attention."""
        return jnp.asarray([1.0 if k == "full" else 0.0
                            for k in self.layer_kinds()], jnp.float32)

    # --------------------------------------------------------------- shapes
    def shapes(self) -> dict[str, dict]:
        out = {}
        for name, s in SHAPES.items():
            if name in self.skip_shapes:
                continue
            if s["kind"] == "decode" and self.family == "audio":
                continue  # encoder-only: no autoregressive step
            out[name] = s
        return out

    def input_specs(self, shape_name: str, *, dtype=jnp.bfloat16) -> dict:
        """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
        s = SHAPES[shape_name]
        B, S = s["batch"], s["seq"]
        kind = s["kind"]
        i32 = jnp.int32

        def sds(shape, dt):
            return jax.ShapeDtypeStruct(shape, dt)

        if self.frontend == "frames":       # audio: precomputed frame embeds
            x = {"frames": sds((B, S, self.frame_dim), dtype),
                 "labels": sds((B, S), i32)}
            return x
        if self.frontend == "patches":      # vlm: patch embeds + text tokens
            text = S - self.n_patches
            if kind == "train":
                return {"patches": sds((B, self.n_patches, self.patch_dim), dtype),
                        "tokens": sds((B, text), i32),
                        "labels": sds((B, text), i32)}
            if kind == "prefill":
                return {"patches": sds((B, self.n_patches, self.patch_dim), dtype),
                        "tokens": sds((B, text), i32)}
            return {"token": sds((B, 1), i32)}   # decode
        # plain token LM
        if kind == "train":
            return {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}
        if kind == "prefill":
            return {"tokens": sds((B, S), i32)}
        return {"token": sds((B, 1), i32)}       # decode: one new token

    # ---------------------------------------------------------------- smoke
    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 2),
            d_model=128,
            n_heads=min(self.n_heads, 4) if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=32 if self.n_heads else 0,
            d_ff=max(self.d_ff and 256, 0),
            vocab=min(self.vocab, 512),
            n_experts=min(self.n_experts, 4),
            moe_top_k=min(self.moe_top_k, 2),
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=32 if self.ssm_state else 64,
            window=64,
            frame_dim=64 if self.frontend == "frames" else self.frame_dim,
            n_patches=8 if self.frontend == "patches" else self.n_patches,
            patch_dim=64 if self.frontend == "patches" else self.patch_dim,
        )

    # -------------------------------------------------------------- counting
    def param_count(self) -> int:
        """Approximate parameter count (embedding + per-layer)."""
        d, L = self.d_model, self.n_layers
        n = self.vocab * d                              # embed (tied head)
        if not self.tie_embeddings:
            n += self.vocab * d
        per = 0
        if self.has_attention:
            hd = self.head_dim_
            per += d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                + self.n_heads * hd * d
        if self.has_ssm:
            din = self.ssm_expand * self.d_model
            per += d * (2 * din + 2 * self.ssm_state) + din * d \
                + self.conv_width * (din + 2 * self.ssm_state)
        if self.n_experts:
            per += d * self.n_experts \
                + self.n_experts * 3 * d * self.d_ff
        elif self.mlp_type == "gated":
            per += 3 * d * self.d_ff
        elif self.mlp_type == "gelu":
            per += 2 * d * self.d_ff
        per += 2 * d                                     # norms
        return n + L * per

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        full = self.param_count()
        moe_all = L * self.n_experts * 3 * d * self.d_ff
        moe_act = L * self.moe_top_k * 3 * d * self.d_ff
        return full - moe_all + moe_act
