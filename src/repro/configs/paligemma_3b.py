"""paligemma-3b [vlm]: 18L d_model=2048 8H (MQA kv=1) d_ff=16384
vocab=257216 — SigLIP + gemma decoder, prefix-LM over patches.
Vision frontend = STUB: input_specs() provides precomputed patch embeddings
[B, 256, patch_dim]. [arXiv:2407.07726; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab=257216,
    attn_pattern=("full",), mlp_type="gated",
    frontend="patches", n_patches=256, patch_dim=1152,
    rope_theta=10_000.0,
    skip_shapes=("long_500k",),   # pure full attention (DESIGN.md §5)
    source="arXiv:2407.07726; hf",
)
