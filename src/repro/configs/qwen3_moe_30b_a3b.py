"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) d_ff(expert)=768
vocab=151936, MoE 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=768, vocab=151936,
    attn_pattern=("full",), mlp_type="gated",
    n_experts=128, moe_top_k=8,
    rope_theta=1_000_000.0,
    skip_shapes=("long_500k",),   # pure full attention (DESIGN.md §5)
    source="hf:Qwen/Qwen3-30B-A3B; hf",
)
