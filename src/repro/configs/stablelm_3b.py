"""stablelm-3b [dense]: 32L d_model=2560 32H (kv=32, i.e. MHA) d_ff=6912
vocab=50304. [hf:stabilityai/stablelm-2-1_6b; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-3b", family="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=6912, vocab=50304,
    attn_pattern=("full",), mlp_type="gated", norm_type="layer",
    rope_theta=10_000.0,
    skip_shapes=("long_500k",),   # pure full attention (DESIGN.md §5)
    source="hf:stabilityai/stablelm-2-1_6b; unverified",
)
