"""Assigned architecture registry: --arch <id> resolves here."""
from repro.configs.base import ArchConfig, SHAPES


def _load(name: str) -> ArchConfig:
    import importlib
    mod = importlib.import_module(f"repro.configs.{name.replace('-', '_')}")
    return mod.CONFIG


ARCH_IDS = [
    "gemma3-4b", "starcoder2-15b", "gemma3-27b", "stablelm-3b",
    "grok-1-314b", "qwen3-moe-30b-a3b", "hymba-1.5b", "hubert-xlarge",
    "mamba2-780m", "paligemma-3b",
]


def get_config(arch: str) -> ArchConfig:
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCH_IDS}")
    return _load(arch.replace("-", "_").replace(".", "_"))


def all_configs() -> dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


__all__ = ["ArchConfig", "SHAPES", "ARCH_IDS", "get_config", "all_configs"]
