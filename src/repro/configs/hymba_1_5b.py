"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — PARALLEL attention+mamba heads per layer.
3 full-attention layers (first/middle/last), rest sliding-window.
Meta-tokens omitted (DESIGN.md §8). [arXiv:2411.13676; hf]"""
from repro.configs.base import ArchConfig
import dataclasses

_pat = tuple("full" if i in (0, 15, 31) else "sw" for i in range(32))

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab=32001,
    attn_pattern=_pat, window=1024, mlp_type="gated",
    ssm_state=16, ssm_head_dim=64, ssm_expand=2, parallel_ssm=True,
    rope_theta=10_000.0,
    source="arXiv:2411.13676; hf",
)
