"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2. [hf:xai-org/grok-1; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=32768, vocab=131072,
    attn_pattern=("full",), mlp_type="gated",
    n_experts=8, moe_top_k=2,
    rope_theta=10_000.0,
    skip_shapes=("long_500k",),   # pure full attention (DESIGN.md §5)
    source="hf:xai-org/grok-1; unverified",
)
