"""PartitionSpec rules for the (pod, data, model) mesh.

One rule table covers every assigned family (dense, MoE, SSM, hybrid,
audio, vlm). Conventions (DESIGN.md §2, asserted by tests/test_dist.py):

  params   FSDP over `data` on the d_model ("in") dim, tensor parallel over
           `model` on the feature ("out") dim; transpose layout for the
           output projections (wo / w_down / fc2 / out_proj) so the TP
           partial-sums reduce over `model`. The embedding shards vocab
           over `model` and d_model over `data`. MoE experts are
           TP-in-expert: [L, E, d(fsdp), f(model)] / w_down transposed,
           router replicated (the sharded dispatch broadcasts it — see
           repro.models.moe:105).
  opt      mirrors the param layout leaf-for-leaf (momentum / adam moments
           have param shapes); scalar counters replicate.
  batch    leading (batch) dim over the batch axes, rest replicated.
  cache    KV cache [L, B, S, KV, hd]: batch over the batch axes and the
           SEQUENCE dim over `model` (flash-decoding layout); SSM state is
           batch-sharded only.

Every rule is guarded by divisibility: an axis that does not evenly divide
its dim is dropped (replicated) rather than producing an invalid layout —
this is what lets the same rules serve smoke configs on a 2×4 test mesh
and full configs on 16×16 pods. `fsdp_axis` may be a tuple of mesh axes
(the pure-DP ZeRO-3 layout shards weights over the whole mesh) and
`model_axis` may be None (no TP).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_FSDP, _TP = "fsdp", "tp"

# Projections whose kernel is [in(d_model → fsdp), out(features → tp)].
_IN_KERNELS = ("wq", "wk", "wv", "w_gate", "w_up", "fc1", "in_proj",
               "head", "frontend", "patch_proj", "wi", "wh")
# Output projections: [in(features → tp), out(d_model → fsdp)].
_OUT_KERNELS = ("wo", "w_down", "fc2", "out_proj")
# Cache leaves carrying a sequence dim at index 2 ([L, B, S, ...]).
_SEQ_CACHE = ("k", "v", "k_scale", "v_scale")


def _path_names(path) -> tuple[str, ...]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return tuple(out)


def _roles(names: tuple[str, ...]) -> tuple:
    """Trailing-dim role tags for one param leaf; leading dims (the [L, ...]
    layer stack, the MoE [E, ...] expert dim) are padded to replicated."""
    last = names[-1] if names else ""
    parent = names[-2] if len(names) > 1 else ""
    if last == "embedding":
        return (_TP, _FSDP)                      # [V(model), d(data)]
    if parent == "moe":                          # raw [E, d, f] expert stacks
        if last in ("w_gate", "w_up"):
            return (_FSDP, _TP)
        if last == "w_down":
            return (_TP, _FSDP)
        return ()                                # router handled via "kernel"
    if last == "kernel":
        if parent in _IN_KERNELS:
            return (_FSDP, _TP)
        if parent in _OUT_KERNELS:
            return (_TP, _FSDP)
    return ()                                    # norms, biases, SSM scalars,
                                                 # router: replicated


def _axis_size(axis, mesh) -> int | None:
    """Total shard count of a mesh-axis entry (str or tuple); None if any
    named axis is absent from this mesh."""
    axes = axis if isinstance(axis, (tuple, list)) else (axis,)
    n = 1
    for a in axes:
        if a not in mesh.shape:
            return None
        n *= mesh.shape[a]
    return n


def _fit(axis, dim: int, mesh):
    """The axis entry if it exists and evenly divides `dim`, else None."""
    if axis is None:
        return None
    n = _axis_size(axis, mesh)
    if n is None or n <= 1 or dim % n != 0:
        return None
    return tuple(axis) if isinstance(axis, (tuple, list)) else axis


def _resolve(roles: tuple, shape, mesh, fsdp_axis, model_axis) -> P:
    ndim = len(shape)
    roles = roles[-ndim:] if len(roles) > ndim else roles
    roles = (None,) * (ndim - len(roles)) + tuple(roles)
    entries = []
    for dim, role in zip(shape, roles):
        axis = fsdp_axis if role == _FSDP else \
            model_axis if role == _TP else None
        entries.append(_fit(axis, dim, mesh))
    return P(*entries)


# ------------------------------------------------------------------- params
def param_specs(params, mesh, *, fsdp_axis="data", model_axis="model"):
    """PartitionSpec pytree mirroring `params` (arrays or ShapeDtypeStructs,
    e.g. from `jax.eval_shape(lm.init, key)`)."""
    def one(path, leaf):
        return _resolve(_roles(_path_names(path)), leaf.shape, mesh,
                        fsdp_axis, model_axis)
    return jax.tree_util.tree_map_with_path(one, params)


# -------------------------------------------------------------------- opt
def opt_state_specs(opt_state, pspecs, mesh):
    """Optimizer-state specs: any sub-tree that is param-shaped (momentum
    buffers, adam moments, master copies) inherits the param layout;
    everything else (step counters) replicates."""
    del mesh  # shapes match params, so the divisibility guard carries over
    is_p = lambda x: isinstance(x, P)
    pdef = jax.tree_util.tree_structure(pspecs, is_leaf=is_p)

    def one(sub):
        if jax.tree_util.tree_structure(sub) == pdef:
            return pspecs
        return jax.tree.map(lambda l: P(*[None] * getattr(l, "ndim", 0)), sub)

    if isinstance(opt_state, dict):
        return {k: one(v) for k, v in opt_state.items()}
    return one(opt_state)


# ------------------------------------------------------------------- batch
def batch_specs(batch, mesh, *, batch_axes=("data",)):
    """Shard every leaf's leading dim over `batch_axes` when divisible."""
    baxes = tuple(a for a in batch_axes if a in mesh.shape)
    n = _axis_size(baxes, mesh) if baxes else 1

    def one(leaf):
        shape = leaf.shape
        if shape and n and n > 1 and shape[0] % n == 0:
            return P(baxes, *[None] * (len(shape) - 1))
        return P(*[None] * len(shape))

    return jax.tree.map(one, batch)


# ------------------------------------------------------------------- cache
def cache_specs(cache, mesh, *, batch_axes=("data",), seq_axis="model"):
    """Decode/prefill cache layout: [L, B(batch), S(model), ...] for KV
    leaves (flash-decoding: the length-S reduction is sequence-sharded over
    `model`), batch-only for SSM state/conv leaves."""
    baxes = tuple(a for a in batch_axes if a in mesh.shape)
    nb = _axis_size(baxes, mesh) if baxes else 1

    def one(path, leaf):
        names = _path_names(path)
        shape = leaf.shape
        entries = [None] * len(shape)
        if len(shape) >= 2 and nb and nb > 1 and shape[1] % nb == 0:
            entries[1] = baxes
        if names and names[-1] in _SEQ_CACHE and len(shape) >= 3:
            entries[2] = _fit(seq_axis, shape[2], mesh)
        return P(*entries)

    return jax.tree_util.tree_map_with_path(one, cache)


# ------------------------------------------------------------------- named
def named(tree, mesh):
    """PartitionSpec pytree (or a single P) → NamedSharding pytree."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))
