"""Sharded-execution layer: maps FedLuck's joint (k, δ) scheme onto a
(pod, data, model) device mesh.

  sharding     FSDP/TP PartitionSpec rules for every pytree the launchers
               move (params, optimizer state, batches, KV caches)
  steps        jit-able train / local-round / prefill / decode step builders
  collectives  the Eq. 6 cross-pod sync (EF top-k sparse reduce) and the
               δ-adaptive sparse/dense wire-cost model

Everything here is GSPMD-first: the step functions are ordinary pure
functions and the launchers pin layouts with `sharding.named(...)` at the
jit boundary, so the same code runs on one CPU device, the 8-device test
mesh, and the 2×16×16 production mesh.
"""
from repro.dist import collectives, sharding, steps

__all__ = ["collectives", "sharding", "steps"]
