"""Cross-pod sync: FedLuck Eq. 6 as a δ-adaptive EF top-k sparse reduce.

Each pod finishes its k local steps with a pseudo-gradient delta (Eq. 4);
the sync compresses every pod's EF accumulator (delta + residual) to
density δ and applies the server rule

    w  ←  w − η_g · mean_pods(kept)          (Eq. 6)
    r' =  (delta + r) − kept                 (error feedback)

The wire format is δ-adaptive (DESIGN.md §4): below the density crossover
the kept entries ship as a (values, indices) sparse all-gather; above it a
dense ring all-reduce is cheaper and the compression only serves the EF
contract. `make_pod_sync` picks the path at build time from the static
rate — the sparse path thresholds per (pod, block) with `lax.top_k` (the
layout the sharded all-gather needs: every in-pod chip owns whole blocks),
the dense path reuses the exact global threshold pipeline from
`repro.kernels.ops.topk_compress`.

`all_gather_bytes` / `density_crossover` are the analytic wire-cost model
(benchmarks/kernel_bench.py plots the crossover).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops

VALUE_BYTES = 4   # fp32 payload
INDEX_BYTES = 4   # int32 in-block offset


def density_crossover(n_pods: int, *, value_bytes: int = VALUE_BYTES,
                      index_bytes: int = INDEX_BYTES) -> float:
    """Density δ* where sparse all-gather bytes == dense ring all-reduce
    bytes. Sparse ships (P−1)·δ·d·(val+idx) per device; the ring costs
    2·(P−1)/P·d·val. With 4-byte values/indices δ* = 1/P."""
    return 2.0 * value_bytes / (n_pods * (value_bytes + index_bytes))


def all_gather_bytes(dim: int, n_pods: int, rate: float, *,
                     value_bytes: int = VALUE_BYTES,
                     index_bytes: int = INDEX_BYTES) -> float:
    """Per-device wire bytes of one Eq. 6 sync at density `rate` — the
    cheaper of the sparse gather and the dense ring all-reduce."""
    k = max(1.0, round(rate * dim))
    sparse = (n_pods - 1) * k * (value_bytes + index_bytes)
    dense = 2.0 * (n_pods - 1) / n_pods * dim * value_bytes
    return float(min(sparse, dense))


def make_pod_sync(mesh, dim: int, *, rate: float, eta_g: float = 1.0,
                  n_blocks: int):
    """Build sync(params, deltas, residuals) -> (new_params, new_residuals).

    params     [n_blocks, blk]            global model (flat, blocked)
    deltas     [n_pods, n_blocks, blk]    per-pod Eq. 4 pseudo-gradients
    residuals  [n_pods, n_blocks, blk]    per-pod EF carry

    dim = n_blocks · blk; the blocked 2D layout shards n_blocks over the
    in-pod axes and the pod dim over `pod`, so the mean over pods lowers
    to the cross-pod collective.
    """
    n_pods = int(mesh.shape["pod"]) if "pod" in mesh.shape else 1
    if dim % n_blocks != 0:
        raise ValueError(f"dim={dim} not divisible by n_blocks={n_blocks}")
    blk = dim // n_blocks
    sparse = rate < density_crossover(max(n_pods, 2))

    def compress_sparse(acc):
        # per-(pod, block) budget: every chip thresholds the blocks it owns
        # locally — no cross-chip threshold traffic, bounded deferral of
        # over-budget blocks' entries to the next round via EF.
        kb = max(1, min(blk, round(rate * blk)))
        mags = jnp.abs(acc)
        thr = jax.lax.top_k(mags, kb)[0][..., -1:]
        return jnp.where(mags >= thr, acc, 0.0)

    def compress_dense(acc_p, res_p):
        # exact global threshold via the Pallas histogram pipeline
        out, _, _, _ = ops.topk_compress(
            (acc_p - res_p).reshape(dim), res_p.reshape(dim), rate=rate)
        return out.reshape(n_blocks, blk)

    def sync(params, deltas, residuals):
        acc = deltas.astype(jnp.float32) + residuals.astype(jnp.float32)
        if sparse:
            kept = compress_sparse(acc)
        else:
            kept = jnp.stack([
                compress_dense(acc[p], residuals[p].astype(jnp.float32))
                for p in range(max(n_pods, 1))])
        new_residuals = acc - kept
        update = jnp.mean(kept, axis=0)          # Eq. 6 cross-pod reduce
        new_params = params - eta_g * update
        return new_params, new_residuals

    return sync
