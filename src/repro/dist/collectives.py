"""Cross-pod sync: FedLuck Eq. 6 as a δ-adaptive EF top-k sparse reduce.

Each pod finishes its k local steps with a pseudo-gradient delta (Eq. 4);
the sync compresses every pod's EF accumulator (delta + residual) to
density δ and applies the server rule

    w  ←  w − η_g · mean_pods(kept)          (Eq. 6)
    r' =  (delta + r) − kept                 (error feedback)

Wire format (compact path)
--------------------------
Below the density crossover the kept entries ship as a **compact
fixed-budget block payload** instead of a dense zero-filled carrier. Per
owned block of `blk` coordinates each chip emits

    values   f32[budget]   kept entries, front-packed in index order
    indices  i32[budget]   shard-local flat coordinates of the values
    count    i32           kept-count header (<= budget)

with `budget = block_budget(blk, δ) = max(1, min(blk, round(δ·blk)))`.
Every chip thresholds only the blocks it owns: one histogram threshold
solve per shard (`kernels.ops.compact_shard_topk`) targeting
`budget · n_owned_blocks` keeps, then the `compact_topk` Pallas kernel
packs each block's survivors into the fixed budget. Padding slots carry
(0.0, 0) — scatter-adding them is a no-op — so
`zeros.at[indices].add(values)` reconstructs the selection exactly, and
blocks whose survivors overflow the budget defer the excess to the next
round through the EF residual (`residual' = acc − shipped`, bitwise). The
collective is a `shard_map` all-gather of ONLY these payloads over the
`pod` axis followed by a local scatter-accumulate: wire bytes scale with
δ, not with d.

Above the crossover a dense ring all-reduce is cheaper and the compression
only serves the EF contract; that path keeps the exact global threshold
pipeline (`kernels.ops.topk_compress`, vmapped over pods).

`make_pod_sync(..., wire=...)` picks the path: "auto" dispatches at build
time on `density_crossover`, "compact"/"dense" force one, and "reference"
is the dense-carrier oracle of the compact selection semantics (same
thresholds and budgets, GSPMD mean instead of the sparse gather) that the
equivalence tests and the `podsync` benchmark gate diff against.

`CompactWire` / `all_gather_bytes` / `density_crossover` are the wire-cost
model. With `n_blocks` given, `all_gather_bytes` counts the actual compact
payload — budget slots plus count headers — so the model and the kernel
agree on the per-block budget by construction
(benchmarks/kernel_bench.py sweeps the crossover into BENCH_podsync.json).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

VALUE_BYTES = 4    # fp32 payload
INDEX_BYTES = 4    # int32 shard-local flat coordinate
HEADER_BYTES = 4   # i32 kept-count per block


def block_budget(blk: int, rate: float) -> int:
    """Fixed per-block slot count of the compact wire format (also the EF
    selection cap): max(1, min(blk, round(rate·blk))). Both the wire-cost
    model and the kernel use this, so they agree by construction."""
    return max(1, min(int(blk), int(round(rate * blk))))


@dataclasses.dataclass(frozen=True)
class CompactWire:
    """Payload shape of one shard's compact sync upload."""
    n_blocks: int   # blocks this shard owns
    blk: int        # coordinates per block
    budget: int     # slots per block (block_budget)

    @property
    def dim(self) -> int:
        return self.n_blocks * self.blk

    def payload_bytes(self) -> int:
        """Bytes one shard ships to one peer: values + indices + headers."""
        return self.n_blocks * (self.budget * (VALUE_BYTES + INDEX_BYTES)
                                + HEADER_BYTES)

    def payload_bits(self) -> int:
        return 8 * self.payload_bytes()


def density_crossover(n_pods: int, *, value_bytes: int = VALUE_BYTES,
                      index_bytes: int = INDEX_BYTES) -> float:
    """Density δ* where compact all-gather bytes == dense ring all-reduce
    bytes. Compact ships (P−1)·δ·d·(val+idx) per device (headers add a
    constant ~HEADER_BYTES/blk per coordinate, negligible for blk ≫ 1);
    the ring costs 2·(P−1)/P·d·val. With 4-byte values/indices δ* = 1/P."""
    return 2.0 * value_bytes / (n_pods * (value_bytes + index_bytes))


def all_gather_bytes(dim: int, n_pods: int, rate: float, *,
                     n_blocks: int = 1, value_bytes: int = VALUE_BYTES,
                     index_bytes: int = INDEX_BYTES) -> float:
    """Per-device wire bytes of one Eq. 6 sync at density `rate` over `dim`
    coordinates in `n_blocks` blocks — the cheaper of the compact gather
    (actual payload: `block_budget` slots + count header per block) and the
    dense ring all-reduce."""
    if dim % n_blocks != 0:
        raise ValueError(f"dim={dim} not divisible by n_blocks={n_blocks}")
    blk = dim // n_blocks
    budget = block_budget(blk, rate)
    compact = (n_pods - 1) * n_blocks * (budget * (value_bytes + index_bytes)
                                         + HEADER_BYTES)
    dense = 2.0 * (n_pods - 1) / n_pods * dim * value_bytes
    return float(min(compact, dense))


def make_pod_sync(mesh, dim: int, *, rate: float, eta_g: float = 1.0,
                  n_blocks: int, wire: str = "auto",
                  interpret: bool | None = None):
    """Build sync(params, deltas, residuals) -> (new_params, new_residuals).

    params     [n_blocks, blk]            global model (flat, blocked)
    deltas     [n_pods, n_blocks, blk]    per-pod Eq. 4 pseudo-gradients
    residuals  [n_pods, n_blocks, blk]    per-pod EF carry

    dim = n_blocks · blk; the blocked 2D layout shards n_blocks over the
    in-pod axes and the pod dim over `pod`, so the mean over pods lowers
    to the cross-pod collective.

    wire: "auto" picks "compact" below `density_crossover` and "dense"
    above; "reference" is the dense-carrier oracle of the compact
    selection (tests / bench gate). The returned fn carries `.path` (the
    resolved wire mode), `.wire` (the per-shard `CompactWire`, None on the
    dense path), `.bytes_per_device` (wire-cost model for one sync), and
    `.payload_bits_per_pod` (bits one pod's whole update occupies on the
    wire — what `dist.steps.make_pod_round_step` charges).
    """
    n_pods = int(mesh.shape["pod"]) if "pod" in mesh.shape else 1
    if dim % n_blocks != 0:
        raise ValueError(f"dim={dim} not divisible by n_blocks={n_blocks}")
    blk = dim // n_blocks
    inpod = tuple(a for a in mesh.axis_names if a != "pod")
    n_shards = int(math.prod(mesh.shape[a] for a in inpod)) if inpod else 1
    has_pod = "pod" in mesh.shape
    if wire == "auto":
        wire = ("compact" if rate < density_crossover(max(n_pods, 2))
                else "dense")
    if wire not in ("compact", "dense", "reference"):
        raise ValueError(f"unknown wire mode {wire!r}")

    budget = block_budget(blk, rate)
    if wire in ("compact", "reference"):
        if n_blocks % n_shards != 0:
            raise ValueError(f"n_blocks={n_blocks} not divisible by the "
                             f"in-pod shard count {n_shards}")
        nbl = n_blocks // n_shards      # blocks each chip owns
        k_shard = nbl * budget          # shard threshold target
        wire_fmt = CompactWire(nbl, blk, budget)
    else:
        wire_fmt = None

    if wire == "compact":
        inpod_entry = inpod if inpod else None
        pspec = jax.sharding.PartitionSpec(inpod_entry, None)
        dspec = jax.sharding.PartitionSpec("pod" if has_pod else None,
                                           inpod_entry, None)

        def shard_fn(p_l, d_l, r_l):
            with jax.named_scope("pod_sync.compact_pack"):
                acc = d_l[0].astype(jnp.float32) + r_l[0].astype(jnp.float32)
                vals, idx, _, res = ops.compact_shard_topk(
                    acc, budget=budget, interpret=interpret)
            with jax.named_scope("pod_sync.all_gather"):
                if has_pod:
                    vals = jax.lax.all_gather(vals, "pod")  # [P, nbl, budget]
                    idx = jax.lax.all_gather(idx, "pod")
                else:
                    vals, idx = vals[None], idx[None]
            with jax.named_scope("pod_sync.scatter_apply"):
                upd = jnp.zeros((acc.size,), jnp.float32).at[
                    idx.reshape(-1)].add(vals.reshape(-1)) / n_pods
                new_p = (p_l - eta_g * upd.reshape(acc.shape)) \
                    .astype(p_l.dtype)
            return new_p, res[None].astype(r_l.dtype)

        mapped = jax.shard_map(shard_fn, mesh=mesh,
                               in_specs=(pspec, dspec, dspec),
                               out_specs=(pspec, dspec), check_vma=False)

        def sync(params, deltas, residuals):
            return mapped(params, deltas, residuals)

    elif wire == "reference":
        def sync(params, deltas, residuals):
            acc = deltas.astype(jnp.float32) + residuals.astype(jnp.float32)
            accs = acc.reshape(n_pods, n_shards, nbl, blk)

            def one_shard(a):
                t = ops.solve_threshold(a.reshape(-1), k_shard,
                                        interpret=interpret)
                _, _, _, res = ref.ref_compact_blocks(a, t, budget)
                return a - res   # shipped selection, dense carrier

            kept = jax.vmap(jax.vmap(one_shard))(accs) \
                .reshape(n_pods, n_blocks, blk)
            new_residuals = acc - kept
            update = jnp.mean(kept, axis=0)          # Eq. 6 reduce
            return params - eta_g * update, new_residuals

    else:  # dense ring: exact global threshold, dense GSPMD mean
        def compress_dense(acc_p, res_p):
            kw = {} if interpret is None else {"interpret": interpret}
            out, _, _, _ = ops.topk_compress(
                (acc_p - res_p).reshape(dim), res_p.reshape(dim), rate=rate,
                **kw)
            return out.reshape(n_blocks, blk)

        def sync(params, deltas, residuals):
            with jax.named_scope("pod_sync.dense"):
                acc = deltas.astype(jnp.float32) \
                    + residuals.astype(jnp.float32)
                kept = jax.vmap(compress_dense)(
                    acc, residuals.astype(jnp.float32))
                new_residuals = acc - kept
                update = jnp.mean(kept, axis=0)      # Eq. 6 cross-pod reduce
                return params - eta_g * update, new_residuals

    sync.path = wire
    sync.wire = wire_fmt
    if wire_fmt is not None:
        sync.bytes_per_device = float(
            (max(n_pods, 1) - 1) * wire_fmt.payload_bytes())
        sync.payload_bits_per_pod = float(n_shards * wire_fmt.payload_bits())
    else:
        dim_local = dim // n_shards
        sync.bytes_per_device = \
            2.0 * (n_pods - 1) / max(n_pods, 1) * dim_local * VALUE_BYTES
        sync.payload_bits_per_pod = float(dim) * 8.0 * VALUE_BYTES
    return sync
