"""Jit-able step builders for the sharded launchers.

Each builder closes over an `LM` facade (and optimizer) and returns a pure
function the caller jits with explicit in/out shardings (see
launch/dryrun.py). The builders add exactly the structure GSPMD cannot
infer on its own:

  make_train_step        fwd/bwd/update; optional ZeRO-3 whole-tree gather
                         (one explicit all-gather per param at step start)
                         and a `microbatches=` lax.scan gradient-accumulation
                         path with fp32 accumulators.
  make_local_round_step  FedLuck Alg. 1 device loop: k SGD steps over a
                         stacked [k, B, ...] batch, returning the Eq. 4
                         pseudo-gradient delta = w0 − wk in fp32.
  make_pod_round_step    one full FedLuck datacenter round: vmapped per-pod
                         local rounds feeding the Eq. 6 cross-pod sync from
                         dist.collectives, with wire bits taken from the
                         sync's actual compact payload shape.
  make_prefill_step /    thin inference wrappers (the KV-cache layout work
  make_decode_step       lives in sharding.cache_specs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _strip_axes(spec: P, axes) -> P:
    """Remove mesh axes in `axes` from a PartitionSpec (→ gather them)."""
    drop = set(axes)

    def one(entry):
        if entry is None:
            return None
        names = tuple(entry) if isinstance(entry, (tuple, list)) else (entry,)
        kept = tuple(a for a in names if a not in drop)
        if not kept:
            return None
        return kept[0] if len(kept) == 1 else kept

    return P(*[one(e) for e in spec])


def _zeros_f32_like(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def make_train_step(lm, opt, *, microbatches: int = 1, pspec=None,
                    zero3_axes=None):
    """step(params, opt_state, batch) -> (new_params, new_opt_state, loss).

    zero3_axes: mesh axes the params are *additionally* sharded over at
    rest; the step gathers them once up front (a single per-param
    all-gather in the schedule) by re-constraining to `pspec` with those
    axes stripped. microbatches: split the batch leading dim into n chunks
    and accumulate grads/loss in fp32 — same numbers as the full-batch
    step, ~n× less activation memory.
    """
    if zero3_axes and pspec is None:
        raise ValueError("zero3_axes requires pspec")
    gather_spec = None
    if zero3_axes:
        gather_spec = jax.tree.map(lambda s: _strip_axes(s, zero3_axes),
                                   pspec, is_leaf=lambda x: isinstance(x, P))

    def step(params, opt_state, batch):
        if gather_spec is not None:
            params = jax.lax.with_sharding_constraint(params, gather_spec)
        if microbatches <= 1:
            loss, grads = jax.value_and_grad(lm.loss)(params, batch)
        else:
            stacked = jax.tree.map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)

            def accum(carry, mb):
                loss_sum, gsum = carry
                l, g = jax.value_and_grad(lm.loss)(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (loss_sum + l.astype(jnp.float32), gsum), None

            (loss_sum, gsum), _ = jax.lax.scan(
                accum, (jnp.zeros((), jnp.float32), _zeros_f32_like(params)),
                stacked)
            loss = loss_sum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
        new_params, new_state = opt.update(grads, opt_state, params)
        return new_params, new_state, loss

    return step


def make_local_round_step(lm, opt, k: int):
    """round(params, opt_state, batches) -> (params_k, opt_state_k, delta,
    mean_loss) where batches is a pytree of [k, B, ...] arrays and
    delta = w0 − wk (fp32) is the Eq. 4 pseudo-gradient the caller
    compresses and ships (train.py datacenter mode, Eq. 6 server rule
    w ← w − η_g/|S| Σ g̃)."""

    def round_fn(params, opt_state, batches):
        def body(carry, batch):
            p, s = carry
            loss, grads = jax.value_and_grad(lm.loss)(p, batch)
            p, s = opt.update(grads, s, p)
            return (p, s), loss

        with jax.named_scope("local_round"):
            (p_k, s_k), losses = jax.lax.scan(body, (params, opt_state),
                                              batches, length=k)
            delta = jax.tree.map(
                lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
                params, p_k)
        return p_k, s_k, delta, jnp.mean(losses)

    return round_fn


def make_pod_round_step(lm, opt, k: int, sync, *, spec, dim: int,
                        n_blocks: int):
    """Compose local rounds and the cross-pod sync into one jit-able round.

    `sync` comes from `dist.collectives.make_pod_sync`; `spec` is the
    flatten spec of the params pytree (`compression.flatten_pytree`);
    `dim` is the true flat dim (padded up to n_blocks · blk inside).

    step(params_blocked [nb, blk], opt_states (pod-stacked pytree),
         batches (pod-stacked [P, k, B, ...] pytree),
         residuals [P, nb, blk])
      -> (new_params_blocked, new_opt_states, new_residuals, mean_loss)

    The per-round communication cost is static — `step.wire_bits_per_pod`
    re-exports `sync.payload_bits_per_pod`, the bits one pod's update
    actually occupies on the wire (compact payload: budget slots + count
    headers), replacing the analytic δ·d·32 estimate.
    """
    from repro.core import compression as C

    local = make_local_round_step(lm, opt, k)

    def step(params_blocked, opt_states, batches, residuals):
        nb, blk = params_blocked.shape
        params = C.unflatten_pytree(params_blocked.reshape(-1)[:dim], spec)

        def one_pod(opt_state, pod_batches):
            _, s_k, delta, loss = local(params, opt_state, pod_batches)
            flat_delta, _ = C.flatten_pytree(delta)
            return s_k, flat_delta, loss

        new_states, flat_deltas, losses = jax.vmap(one_pod)(opt_states,
                                                            batches)
        pad = nb * blk - dim
        if pad:
            flat_deltas = jnp.pad(flat_deltas, ((0, 0), (0, pad)))
        deltas = flat_deltas.reshape(-1, nb, blk)
        new_blocked, new_residuals = sync(params_blocked, deltas, residuals)
        return new_blocked, new_states, new_residuals, jnp.mean(losses)

    step.wire_bits_per_pod = float(getattr(sync, "payload_bits_per_pod",
                                           0.0))
    return step


def make_prefill_step(lm):
    """prefill(params, batch) -> (last-position logits [B,1,V], cache)."""
    def prefill(params, batch):
        return lm.prefill(params, batch)
    return prefill


def make_decode_step(lm):
    """decode(params, cache, token [B,1], cur_index) -> (logits, cache).
    The cache arrives sequence-sharded over `model` (sharding.cache_specs);
    the length-S attention reduction runs flash-decoding style, one shard
    per TP device."""
    def decode(params, cache, token, cur_index):
        return lm.decode_step(params, cache, token, cur_index)
    return decode
