"""Pallas TPU kernel: fused momentum-SGD update (server/device hot loop).

    mu' = momentum * mu + g
    w'  = w - lr * mu'

One streaming pass: read (w, mu, g), write (w', mu') — 3R+2W HBM traffic
versus >=5R+4W for the unfused tree_map pair. lr/momentum are compile-time
constants (closed over), matching how the update is jitted per plan.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 8 * 1024


def _make_kernel(lr: float, momentum: float):
    def kernel(w_ref, mu_ref, g_ref, w_out, mu_out):
        mu = momentum * mu_ref[...].astype(jnp.float32) \
            + g_ref[...].astype(jnp.float32)
        w = w_ref[...].astype(jnp.float32) - lr * mu
        mu_out[...] = mu.astype(mu_out.dtype)
        w_out[...] = w.astype(w_out.dtype)
    return kernel


@functools.partial(jax.jit,
                   static_argnames=("lr", "momentum", "block", "interpret"))
def fused_momentum(w: jax.Array, mu: jax.Array, g: jax.Array, *,
                   lr: float, momentum: float = 0.9,
                   block: int = DEFAULT_BLOCK, interpret: bool = False):
    """Flat [d] update. Returns (w', mu')."""
    d = w.shape[0]
    pad = (-d) % block
    if pad:
        z = lambda x: jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
        w, mu, g = z(w), z(mu), z(g)
    nblocks = w.shape[0] // block
    shp = (nblocks, block)
    spec = pl.BlockSpec((1, block), lambda i: (i, 0))

    w2, mu2 = pl.pallas_call(
        _make_kernel(lr, momentum),
        grid=(nblocks,),
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct(shp, w.dtype),
                   jax.ShapeDtypeStruct(shp, mu.dtype)],
        interpret=interpret,
    )(w.reshape(shp), mu.reshape(shp), g.reshape(shp))
    return w2.reshape(-1)[:d], mu2.reshape(-1)[:d]
