"""Pure-jnp oracles for every kernel in repro.kernels (tests diff vs these)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_magnitude_hist(g: jax.Array, edges: jax.Array) -> jax.Array:
    """counts_ge[j] = #{ |g| >= edges[j] }, float32[n_edges]."""
    mag = jnp.abs(g.astype(jnp.float32))
    return jnp.sum(mag[None, :] >= edges.astype(jnp.float32)[:, None],
                   axis=1).astype(jnp.float32)


def ref_ef_topk(g: jax.Array, residual: jax.Array, threshold) -> tuple:
    acc = g.astype(jnp.float32) + residual.astype(jnp.float32)
    keep = jnp.abs(acc) >= jnp.asarray(threshold, jnp.float32)
    out = jnp.where(keep, acc, 0.0)
    res = acc - out
    return out.astype(g.dtype), res.astype(residual.dtype), \
        jnp.sum(keep.astype(jnp.float32))


def ref_fused_momentum(w, mu, g, *, lr: float, momentum: float = 0.9):
    mu_new = momentum * mu.astype(jnp.float32) + g.astype(jnp.float32)
    w_new = w.astype(jnp.float32) - lr * mu_new
    return w_new.astype(w.dtype), mu_new.astype(mu.dtype)


def ref_exact_topk_dense(g: jax.Array, k: int) -> jax.Array:
    """Exact top-k as a dense masked vector (selection oracle)."""
    _, idx = jax.lax.top_k(jnp.abs(g), k)
    out = jnp.zeros_like(g)
    return out.at[idx].set(g[idx])


def ref_threshold_from_hist(counts_ge: jax.Array, edges: jax.Array,
                            k: int) -> jax.Array:
    """Smallest edge whose >=-count reaches k (edges descending)."""
    sel = jnp.argmax(counts_ge >= k)
    return edges[sel]


def ref_compact_blocks(acc: jax.Array, threshold, budget: int) -> tuple:
    """Oracle for kernels.compact_topk.compact_blocks: per-block fixed-budget
    front-pack of the |acc| >= t survivors in index order, shard-local flat
    indices, kept-count header, and the bitwise EF residual."""
    acc = acc.astype(jnp.float32)
    n_blocks, blk = acc.shape
    keep = jnp.abs(acc) >= jnp.asarray(threshold, jnp.float32)
    kf = keep.astype(jnp.float32)
    pos = jnp.cumsum(kf, axis=1) - kf
    in_budget = keep & (pos < budget)
    shipped = jnp.where(in_budget, acc, 0.0)
    cnt = jnp.sum(in_budget, axis=1).astype(jnp.int32)
    # stable pack: kept entries sort to the front by their slot position,
    # dropped entries by a unique key past every slot
    offs = jnp.arange(blk, dtype=jnp.float32)[None, :]
    key = jnp.where(in_budget, pos, blk + offs)
    order = jnp.argsort(key, axis=1)[:, :budget]
    slot_live = jnp.arange(budget, dtype=jnp.int32)[None, :] < cnt[:, None]
    vals = jnp.where(slot_live,
                     jnp.take_along_axis(acc, order, axis=1), 0.0)
    gidx = order.astype(jnp.int32) \
        + (jnp.arange(n_blocks, dtype=jnp.int32) * blk)[:, None]
    idx = jnp.where(slot_live, gidx, 0)
    return vals, idx, cnt, acc - shipped
