"""Pallas TPU kernel: fused error-feedback threshold select (top-k pass 2).

Given the threshold t from pass 1, performs in ONE streaming pass:

    acc       = g + residual            (error feedback accumulate)
    keep      = |acc| >= t
    out       = acc * keep              (what ships to the server)
    residual' = acc * (1 - keep)        (what stays on device)

HBM traffic: read g + residual, write out + residual' — 2R+2W, the minimum.
The unfused reference does accumulate / compare / two selects as separate
HLO ops (>=3R+3W). A second output `nnz` (per-call count) feeds the wire-
bytes accounting and the optional exact-k correction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 8 * 1024


def _ef_topk_kernel(g_ref, r_ref, t_ref, out_ref, res_ref, nnz_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        nnz_ref[...] = jnp.zeros_like(nnz_ref)

    acc = g_ref[...].astype(jnp.float32) + r_ref[...].astype(jnp.float32)
    t = t_ref[0, 0]
    keep = (jnp.abs(acc) >= t)
    kept = jnp.where(keep, acc, 0.0)
    out_ref[...] = kept.astype(out_ref.dtype)
    res_ref[...] = (acc - kept).astype(res_ref.dtype)
    nnz_ref[...] += jnp.sum(keep.astype(jnp.float32), keepdims=True
                            ).reshape(nnz_ref.shape)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def ef_topk(g: jax.Array, residual: jax.Array, threshold: jax.Array, *,
            block: int = DEFAULT_BLOCK, interpret: bool = False):
    """Returns (out, new_residual, nnz) — flat, same dtype as g."""
    d = g.shape[0]
    pad = (-d) % block
    if pad:
        g = jnp.concatenate([g, jnp.zeros((pad,), g.dtype)])
        residual = jnp.concatenate([residual, jnp.zeros((pad,), residual.dtype)])
    nblocks = g.shape[0] // block
    g2 = g.reshape(nblocks, block)
    r2 = residual.reshape(nblocks, block)
    t2 = jnp.asarray(threshold, jnp.float32).reshape(1, 1)

    out, res, nnz = pl.pallas_call(
        _ef_topk_kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblocks, block), g.dtype),
            jax.ShapeDtypeStruct((nblocks, block), residual.dtype),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(g2, r2, t2)
    return out.reshape(-1)[:d], res.reshape(-1)[:d], nnz[0, 0]
