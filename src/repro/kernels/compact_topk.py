"""Pallas TPU kernel: fixed-budget block compaction (compact wire format).

Given a blocked EF accumulator [n_blocks, blk] and a threshold t (from the
magnitude-histogram pipeline), each grid step packs one block's survivors
(|acc| >= t, in index order) into a fixed `budget` of slots and emits the
pod-sync wire payload:

    values   f32[n_blocks, budget]   front-packed kept entries
    indices  i32[n_blocks, budget]   shard-local flat coordinates
    counts   i32[n_blocks, 1]        kept-count header (<= budget)
    residual f32[n_blocks, blk]      acc − shipped (EF carry, bitwise)

Padding slots carry (0.0, 0) so a scatter-add of the full payload onto
zeros reconstructs the shipped selection exactly. Blocks with more
survivors than `budget` truncate in index order; the overflow stays in the
residual and ships next round (bounded deferral — the same EF contract the
threshold pipeline already relies on).

The pack is sort-free: a cumulative-sum over the keep mask assigns each
survivor its output slot, a one-hot [blk, budget] matrix built from that
position lowers the gather to one MXU `dot_general` for the values (each
output slot is a sum of exactly one survivor and zeros — bitwise exact)
and an int32 multiply-sum for the indices (int32 stays exact where an fp32
matmul would round coordinates above 2^24).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _compact_kernel(acc_ref, t_ref, vals_ref, idx_ref, cnt_ref, res_ref, *,
                    budget: int):
    i = pl.program_id(0)
    acc = acc_ref[...].astype(jnp.float32)        # [1, blk]
    blk = acc.shape[-1]
    t = t_ref[0, 0]
    keep = (jnp.abs(acc) >= t).astype(jnp.float32)
    pos = jnp.cumsum(keep, axis=-1) - keep        # output slot per survivor
    in_budget = keep * (pos < budget)
    onehot = in_budget.reshape(blk, 1) * (
        pos.reshape(blk, 1)
        == jax.lax.broadcasted_iota(jnp.float32, (blk, budget), 1))
    vals_ref[...] = jax.lax.dot_general(
        acc, onehot, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    gidx = i * blk + jax.lax.broadcasted_iota(jnp.int32, (blk, budget), 0)
    idx_ref[...] = jnp.sum(onehot.astype(jnp.int32) * gidx, axis=0,
                           keepdims=True)
    cnt_ref[...] = jnp.sum(in_budget).astype(jnp.int32).reshape(1, 1)
    shipped = acc * in_budget
    res_ref[...] = (acc - shipped).astype(res_ref.dtype)


@functools.partial(jax.jit, static_argnames=("budget", "interpret"))
def compact_blocks(acc: jax.Array, threshold: jax.Array, *, budget: int,
                   interpret: bool = False):
    """Returns (values, indices, counts, residual) for acc [n_blocks, blk].

    `indices` are shard-local flat coordinates (block index · blk + offset),
    so `zeros(acc.size).at[indices.ravel()].add(values.ravel())` equals the
    shipped selection `acc − residual` exactly.
    """
    n_blocks, blk = acc.shape
    if not 1 <= budget <= blk:
        raise ValueError(f"budget={budget} outside [1, blk={blk}]")
    t2 = jnp.asarray(threshold, jnp.float32).reshape(1, 1)

    vals, idx, cnt, res = pl.pallas_call(
        functools.partial(_compact_kernel, budget=budget),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1, blk), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, budget), lambda i: (i, 0)),
            pl.BlockSpec((1, budget), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, blk), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, budget), jnp.float32),
            jax.ShapeDtypeStruct((n_blocks, budget), jnp.int32),
            jax.ShapeDtypeStruct((n_blocks, 1), jnp.int32),
            jax.ShapeDtypeStruct((n_blocks, blk), jnp.float32),
        ],
        interpret=interpret,
    )(acc.astype(jnp.float32), t2)
    return vals, idx, cnt[:, 0], res
