"""Pallas TPU kernel: streaming magnitude histogram (top-k pass 1).

Computes counts_ge[j] = #{ |g| >= edges[j] } over a flat gradient, streamed
through VMEM block by block. This is the first pass of the TPU-native
threshold top-k (DESIGN.md §3): the paper's GPU sort-based top-k does not
map to the TPU memory hierarchy, so we select by threshold instead.

Grid iterations on TPU run sequentially per core, so the kernel accumulates
into a single output block (index_map pinned to 0); iteration 0 initializes.

VMEM budget per step (defaults): block 8*1024 fp32 elems (32 KiB) + the
broadcast compare [block, n_edges] bf16-free bool workspace — compares are
done per-edge-chunk to stay < 4 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 8 * 1024


def _hist_kernel(x_ref, edges_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    mag = jnp.abs(x_ref[...].astype(jnp.float32))      # [1, block]
    edges = edges_ref[...].astype(jnp.float32)         # [1, n_edges]
    # counts_ge[j] = sum_b  (mag[b] >= edges[j]);  [block,1] >= [1,n_edges]
    ge = (mag.reshape(-1, 1) >= edges.reshape(1, -1)).astype(jnp.float32)
    out_ref[...] += jnp.sum(ge, axis=0, keepdims=True)  # [1, n_edges]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def magnitude_hist(g: jax.Array, edges: jax.Array, *,
                   block: int = DEFAULT_BLOCK,
                   interpret: bool = False) -> jax.Array:
    """counts_ge: float32[n_edges]; g: flat [d] (any float dtype),
    edges: [n_edges] strictly positive descending thresholds."""
    d = g.shape[0]
    n_edges = edges.shape[0]
    pad = (-d) % block
    if pad:
        # zeros are below every (positive) edge: they never count
        g = jnp.concatenate([g, jnp.zeros((pad,), g.dtype)])
    nblocks = g.shape[0] // block
    g2 = g.reshape(nblocks, block)
    e2 = edges.reshape(1, n_edges)

    out = pl.pallas_call(
        _hist_kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, n_edges), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, n_edges), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n_edges), jnp.float32),
        interpret=interpret,
    )(g2, e2)
    return out[0]
