"""Jit'd public wrappers around the Pallas kernels.

`topk_compress` is the full TPU-native top-k pipeline (DESIGN.md §3):

  pass 0  gmax = max|g|                       (XLA reduce)
  pass 1  coarse log2-bucket histogram        (magnitude_hist kernel)
  pass 2  fine linear histogram inside bucket (magnitude_hist kernel)
  solve   threshold t s.t. #{|g+r| >= t} ~= δ·d   (O(buckets), on-chip)
  pass 3  fused EF select                     (ef_topk kernel)

On CPU (this container) kernels run with interpret=True; on TPU they
compile to Mosaic. All wrappers are shape-polymorphic over flat [d] inputs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.compact_topk import compact_blocks
from repro.kernels.ef_topk import ef_topk
from repro.kernels.fused_momentum import fused_momentum
from repro.kernels.magnitude_hist import magnitude_hist

INTERPRET = jax.default_backend() == "cpu"


def _solve_threshold(counts_ge: jax.Array, edges: jax.Array, k) -> tuple:
    """Pick (lo, hi) bracket: largest edge with count >= k and the edge
    above it. edges descending; counts_ge monotone nondecreasing."""
    reached = counts_ge >= k
    sel = jnp.argmax(reached)                  # first True (or 0 if none)
    any_reached = jnp.any(reached)
    sel = jnp.where(any_reached, sel, edges.shape[0] - 1)
    hi = edges[jnp.maximum(sel - 1, 0)]
    lo = edges[sel]
    return lo, hi


@functools.partial(jax.jit,
                   static_argnames=("coarse_buckets", "fine_buckets",
                                    "block", "interpret"))
def solve_threshold(acc: jax.Array, k, *, coarse_buckets: int = 48,
                    fine_buckets: int = 128, block: int = 8 * 1024,
                    interpret: bool | None = None) -> jax.Array:
    """Histogram-pipeline threshold t with #{|acc| >= t} ≈ k (passes 0–2 of
    the top-k pipeline; `k` may be traced). Shared by `topk_compress` and
    the pod-sync compact path, so both select against identical thresholds.
    """
    if interpret is None:
        interpret = INTERPRET
    gmax = jnp.max(jnp.abs(acc)) + 1e-30

    # pass 1: coarse log2 buckets
    coarse_edges = gmax * 2.0 ** (-jnp.arange(coarse_buckets + 1,
                                              dtype=jnp.float32))
    c_counts = magnitude_hist(acc, coarse_edges, block=block,
                              interpret=interpret)
    lo, hi = _solve_threshold(c_counts, coarse_edges, k)

    # pass 2: fine linear buckets inside [lo, hi]
    frac = jnp.arange(fine_buckets + 1, dtype=jnp.float32) / fine_buckets
    fine_edges = hi - (hi - lo) * frac         # descending hi -> lo
    fine_edges = jnp.maximum(fine_edges, 1e-30)
    f_counts = magnitude_hist(acc, fine_edges, block=block,
                              interpret=interpret)
    _, t = _solve_threshold(f_counts, fine_edges, k)
    return t


@functools.partial(jax.jit,
                   static_argnames=("rate", "coarse_buckets", "fine_buckets",
                                    "block", "interpret"))
def topk_compress(g: jax.Array, residual: jax.Array, *, rate: float,
                  coarse_buckets: int = 48, fine_buckets: int = 128,
                  block: int = 8 * 1024, interpret: bool | None = None):
    """Error-feedback threshold top-k at density `rate` (δ = k/d).

    Returns (out_dense, new_residual, nnz, threshold). Selection matches
    exact top-|.|-k up to threshold-resolution ties: nnz ∈ [~k, k(1+ε)]
    with ε bounded by the fine bucket width (tested in test_kernels).
    """
    if interpret is None:
        interpret = INTERPRET
    d = g.shape[0]
    k = max(1, min(d, int(round(rate * d))))
    # NOTE: threshold statistics must be over the EF accumulator, since
    # pass 3 selects on |g + residual|.
    acc_stat_src = g.astype(jnp.float32) + residual.astype(jnp.float32)
    t = solve_threshold(acc_stat_src, k, coarse_buckets=coarse_buckets,
                        fine_buckets=fine_buckets, block=block,
                        interpret=interpret)
    out, new_res, nnz = ef_topk(g, residual, t, block=block,
                                interpret=interpret)
    return out, new_res, nnz, t


def compact_topk(dense: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Compact a dense masked vector to the (values, indices) wire format.

    Picks the `k` largest-|.| coordinates of `dense`; when nnz(dense) <= k
    the extra slots carry zero values (scatter-adding them is a no-op), so
    `zeros(d).at[indices].add(values)` reconstructs `dense` exactly. This is
    the compact pair the simulator ships off-device instead of a d-length
    vector, and the wire format the ROADMAP pod-sync item calls for.
    jit-safe and vmap-safe (k static).
    """
    _, idx = jax.lax.top_k(jnp.abs(dense), k)
    return dense[idx], idx.astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("rate", "coarse_buckets", "fine_buckets",
                                    "block", "interpret", "slack"))
def topk_compress_sparse(g: jax.Array, residual: jax.Array, *, rate: float,
                         coarse_buckets: int = 48, fine_buckets: int = 128,
                         block: int = 8 * 1024, interpret: bool | None = None,
                         slack: float = 1.05):
    """`topk_compress` returning the compact (values, indices) wire pair.

    Returns (values, indices, new_residual, nnz, threshold) with
    len(values) == ceil(slack·k)+8: the histogram threshold can overshoot k
    by ties within one fine bucket, so the capacity carries a small slack.
    Callers can check `nnz` against the capacity; coordinates beyond it
    (never observed at the tested rates) would be dropped from the wire but
    remain accounted in `new_residual` only via the dense pipeline output.
    """
    out, new_res, nnz, t = topk_compress(
        g, residual, rate=rate, coarse_buckets=coarse_buckets,
        fine_buckets=fine_buckets, block=block, interpret=interpret)
    d = g.shape[0]
    k = max(1, min(d, int(round(rate * d))))
    k_cap = min(d, int(k * slack) + 8)
    vals, idx = compact_topk(out, k_cap)
    return vals, idx, new_res, nnz, t


@functools.partial(jax.jit,
                   static_argnames=("budget", "coarse_buckets",
                                    "fine_buckets", "block", "interpret"))
def compact_shard_topk(acc: jax.Array, *, budget: int,
                       coarse_buckets: int = 48, fine_buckets: int = 128,
                       block: int = 8 * 1024, interpret: bool | None = None):
    """Per-shard compact top-k over a blocked EF accumulator [nb, blk].

    Runs the histogram threshold pipeline over the whole shard targeting
    `nb · budget` keeps, then packs each block's survivors into `budget`
    fixed slots (compact_topk kernel). Returns (values [nb, budget],
    indices [nb, budget] i32 shard-local flat, counts [nb] i32 header,
    residual [nb, blk]) — the pod-sync wire payload plus the EF carry.
    """
    if interpret is None:
        interpret = INTERPRET
    with jax.named_scope("compact_shard_topk"):
        nb, blk = acc.shape
        acc = acc.astype(jnp.float32)
        t = solve_threshold(acc.reshape(-1), nb * budget,
                            coarse_buckets=coarse_buckets,
                            fine_buckets=fine_buckets, block=block,
                            interpret=interpret)
        return compact_blocks(acc, t, budget=budget, interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("lr", "momentum", "block", "interpret"))
def momentum_update(w: jax.Array, mu: jax.Array, g: jax.Array, *, lr: float,
                    momentum: float = 0.9, block: int = 8 * 1024,
                    interpret: bool | None = None):
    if interpret is None:
        interpret = INTERPRET
    with jax.named_scope("fused_momentum"):
        return fused_momentum(w, mu, g, lr=lr, momentum=momentum,
                              block=block, interpret=interpret)
