import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# NOTE: the two lines above MUST run before any other import — jax locks the
# device count at first initialization (see system spec, MULTI-POD DRY-RUN).

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces (and caches to results/dryrun/*.json):
  - memory_analysis (bytes per device: args/outputs/temps) — proves it fits
  - cost_analysis  (per-device HLO FLOPs / bytes accessed)
  - the collective schedule: per-op counts + per-device bytes, parsed from
    the SPMD-partitioned HLO (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute)
  - derived roofline terms (v5e constants; see benchmarks/roofline.py)

Usage:
  python -m repro.launch.dryrun --arch gemma3-4b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--force]
  python -m repro.launch.dryrun --sync-step --arch gemma3-4b   # FedLuck Eq.6

The `--all` driver runs each cell in a fresh subprocess (compiles leak
memory on a 1-core host) and tolerates per-cell failures.
"""
import argparse
import json
import re
import subprocess
import sys
import time
import traceback

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

# ------------------------------------------------------- HLO collective parse
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8\w*|s32|s64|u32|u8|s8|pred|s16|u16)"
                       r"\[([0-9,]*)\]")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s64": 8, "u8": 1, "s8": 1, "pred": 1, "s16": 2, "u16": 2}
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _bytes_of_types(s: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(s):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt[:4], _DTYPE_BYTES.get(dt[:3], 4))
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum per-device RESULT bytes of every collective op in the optimized
    (post-SPMD) module, keyed by op kind. The result type annotation sits
    between '=' and the opcode: `%x = f32[16,128]{1,0} all-reduce(...)`.

    NOTE: ops inside while-loop (scan) bodies appear ONCE here; run_cell
    extrapolates true totals from unrolled L1/L2 auxiliary lowerings.
    """
    out = {k: {"count": 0, "bytes": 0} for k in _COLL_KINDS}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        rhs = ls.split("=", 1)[1]
        for kind in _COLL_KINDS:
            m = re.search(rf"\b{kind}(-start)?\(", rhs)
            if m:
                b = _bytes_of_types(rhs[:m.start()])
                out[kind]["count"] += 1
                out[kind]["bytes"] += b
                break
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def _cost_dict(ca) -> dict:
    """compiled.cost_analysis() returns a dict on current jax but a
    per-computation list on 0.4.x — normalize to the dict."""
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


# ------------------------------------------------------------- cell execution
def run_cell(arch: str, shape: str, mesh_kind: str, *, verbose: bool = True,
             step_override: str | None = None, zero3: bool = False,
             moe_local: bool = False, seq_parallel: bool = True,
             layout: str = "tp", microbatches: int = 1,
             kv_int8: bool = False, tag: str = "") -> dict:
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_config
    from repro.dist import sharding as shl
    from repro.dist.steps import (make_decode_step, make_prefill_step,
                                  make_train_step)
    from repro.launch.mesh import batch_axes_for, make_production_mesh
    from repro.models.transformer import LM
    from repro.optim import momentum_sgd

    import dataclasses as _dc

    t0 = time.perf_counter()
    cfg = get_config(arch)
    from repro.configs.base import SHAPES
    sinfo = SHAPES[shape]
    if shape in cfg.skip_shapes or (
            sinfo["kind"] == "decode" and cfg.family == "audio"):
        return {"arch": arch, "shape": shape, "mesh": mesh_kind,
                "status": "skipped", "reason": "see DESIGN.md §5"}

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    # layout "tp": batch over (pod, data), TP+SP over model (default).
    # layout "dp": batch covers the WHOLE mesh; params FSDP over all axes,
    # streamed per-layer ZeRO-3 gather inside the scan (train only).
    if layout == "dp":
        baxes = tuple(a for a in ("pod", "data", "model")
                      if a in mesh.axis_names)
        fsdp_axis, model_axis = baxes, None
    else:
        baxes = batch_axes_for(mesh)
        fsdp_axis, model_axis = "data", "model"
    kind = step_override or sinfo["kind"]
    B, S = sinfo["batch"], sinfo["seq"]
    ns = lambda tree: shl.named(tree, mesh)

    # pin activation batch sharding only when the batch divides the shards
    n_bshards = 1
    for a in baxes:
        n_bshards *= mesh.shape[a]
    act_axes = baxes if B % n_bshards == 0 else None
    # Megatron sequence parallelism on the residual stream for full-sequence
    # steps: cuts per-device activation temps ~7x (30.7 -> 4.6 GiB on
    # stablelm train_4k) so every cell fits v5e HBM.
    seq_axis = "model" if (seq_parallel and layout == "tp"
                           and kind in ("train", "prefill")) else None

    def lower_one(cfg_l, *, use_scan: bool):
        lm = LM(cfg_l, dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
                remat=True, use_scan=use_scan, batch_axes=act_axes,
                act_seq_axis=seq_axis,
                kv_dtype=("int8" if kv_int8 else "compute"),
                zero3_layer=(layout == "dp"),
                moe_dispatch_axes=(act_axes if moe_local and act_axes
                                   else None))
        params_shape = jax.eval_shape(lm.init, jax.random.key(0))
        pspec = shl.param_specs(params_shape, mesh, fsdp_axis=fsdp_axis,
                                model_axis=model_axis)
        if layout == "dp":
            layer_specs = jax.tree.map(
                lambda s: P(*s[1:]), pspec["layers"],
                is_leaf=lambda x: isinstance(x, P))
            lm = _dc.replace(lm, layer_param_specs=layer_specs)
        batch_sds = cfg_l.input_specs(shape)
        bspec = shl.batch_specs(batch_sds, mesh, batch_axes=baxes)
        with jax.set_mesh(mesh):
            if kind == "train":
                opt = momentum_sgd(1e-2, momentum=0.9)
                opt_shape = jax.eval_shape(opt.init, params_shape)
                ospec = shl.opt_state_specs(opt_shape, pspec, mesh)
                # dp layout: the per-layer explicit gathers live INSIDE
                # the scan; no outer whole-tree gather (it double-gathers).
                z3 = act_axes if zero3 and layout == "tp" and act_axes \
                    else None
                fn = make_train_step(lm, opt, pspec=pspec, zero3_axes=z3,
                                     microbatches=microbatches)
                jf = jax.jit(fn,
                             in_shardings=(ns(pspec), ns(ospec), ns(bspec)),
                             out_shardings=(ns(pspec), ns(ospec), ns(P())),
                             donate_argnums=(0, 1))
                lowered = jf.lower(params_shape, opt_shape, batch_sds)
            elif kind == "prefill":
                fn = make_prefill_step(lm)
                cache_shape = lm.cache_specs(B, S)
                cspec = shl.cache_specs(cache_shape, mesh, batch_axes=baxes)
                jf = jax.jit(fn, in_shardings=(ns(pspec), ns(bspec)),
                             out_shardings=(ns(P(baxes)), ns(cspec)))
                lowered = jf.lower(params_shape, batch_sds)
            else:  # decode
                fn = make_decode_step(lm)
                cache_shape = lm.cache_specs(B, S)
                cspec = shl.cache_specs(cache_shape, mesh, batch_axes=baxes)
                tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
                idx = jax.ShapeDtypeStruct((), jnp.int32)
                tspec = shl.batch_specs({"t": tok}, mesh,
                                        batch_axes=baxes)["t"]
                jf = jax.jit(fn,
                             in_shardings=(ns(pspec), ns(cspec), ns(tspec),
                                           ns(P())),
                             out_shardings=(ns(P()), ns(cspec)),
                             donate_argnums=(1,))
                lowered = jf.lower(params_shape, cache_shape, tok, idx)
            return lowered.compile()

    # ---- main lowering: full depth, scanned (memory + schedule + timing)
    compiled = lower_one(cfg, use_scan=True)
    t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    cost = _cost_dict(compiled.cost_analysis())
    coll = parse_collectives(compiled.as_text())

    # ---- cost extrapolation: HLO cost analysis visits a while-loop (scan)
    # body ONCE, so flops/bytes/collectives of the scanned layers are under-
    # counted. Lower unrolled 1- and 2-layer variants; the L2−L1 delta is
    # the exact per-layer cost; total = L1 + (L−1)·Δ.
    t1 = time.perf_counter()
    c1 = lower_one(_dc.replace(cfg, n_layers=1), use_scan=False)
    c2 = lower_one(_dc.replace(cfg, n_layers=2), use_scan=False)
    cost1 = _cost_dict(c1.cost_analysis())
    cost2 = _cost_dict(c2.cost_analysis())
    coll1 = parse_collectives(c1.as_text())
    coll2 = parse_collectives(c2.as_text())
    L = cfg.n_layers

    def extrap(v1, v2):
        return v1 + (L - 1) * (v2 - v1)

    flops_dev = extrap(cost1.get("flops", 0.0), cost2.get("flops", 0.0))
    bytes_dev = extrap(cost1.get("bytes accessed", 0.0),
                       cost2.get("bytes accessed", 0.0))
    coll_bytes_dev = extrap(coll1["total_bytes"], coll2["total_bytes"])
    t_aux = time.perf_counter() - t1

    n_dev = 512 if mesh_kind == "multi" else 256
    res = {
        "arch": arch, "shape": shape, "mesh": mesh_kind, "kind": kind,
        "variant": {"zero3": zero3, "moe_local": moe_local, "layout": layout,
                    "seq_parallel": seq_parallel, "kv_int8": kv_int8,
                    "microbatches": microbatches, "tag": tag},
        "status": "ok", "n_devices": n_dev,
        "compile_s": round(t_compile, 1), "aux_compile_s": round(t_aux, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        },
        "cost": {
            # raw (scan-body-once) numbers, kept for reference
            "raw_flops_per_device": cost.get("flops"),
            "raw_bytes_per_device": cost.get("bytes accessed"),
            # extrapolated true per-device totals
            "flops_per_device": flops_dev,
            "bytes_accessed_per_device": bytes_dev,
            "collective_bytes_per_device": coll_bytes_dev,
        },
        "collectives": coll,
        "collectives_L1": coll1, "collectives_L2": coll2,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    if verbose:
        m = res["memory"]
        live = (m["argument_bytes"] or 0) + (m["temp_bytes"] or 0)
        print(f"[{arch} × {shape} × {mesh_kind}] OK "
              f"compile={t_compile:.0f}s(+{t_aux:.0f}s aux) "
              f"mem/dev={live/2**30:.2f}GiB "
              f"flops/dev={flops_dev:.3e} "
              f"coll/dev={coll_bytes_dev/2**20:.1f}MiB")
        print("  memory_analysis:", {k: v for k, v in m.items() if v})
        print("  collective schedule (scanned module):",
              {k: v for k, v in coll.items()
               if isinstance(v, dict) and v["count"]})
    return res


def run_sync_step(arch: str, *, rate: float = 0.01, verbose=True) -> dict:
    """Lower the FedLuck cross-pod sync (Eq. 6) on the multi-pod mesh."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.dist.collectives import make_pod_sync
    from repro.launch.mesh import make_production_mesh

    t0 = time.perf_counter()
    cfg = get_config(arch)
    dim = cfg.param_count()
    # sharding-aligned 2D layout: n_blocks sharded over the 256 in-pod chips
    n_blocks = 4096
    blk = -(-dim // n_blocks)
    dim_p = n_blocks * blk
    mesh = make_production_mesh(multi_pod=True)
    n_pods = mesh.shape["pod"]
    sync = make_pod_sync(mesh, dim_p, rate=rate, n_blocks=n_blocks)
    p_sds = jax.ShapeDtypeStruct((n_blocks, blk), jnp.float32)
    d_sds = jax.ShapeDtypeStruct((n_pods, n_blocks, blk), jnp.float32)
    from jax.sharding import NamedSharding, PartitionSpec as P
    inpod = ("data", "model")
    p_sh = NamedSharding(mesh, P(inpod, None))
    d_sh = NamedSharding(mesh, P("pod", inpod, None))
    with jax.set_mesh(mesh):
        lowered = jax.jit(sync, in_shardings=(p_sh, d_sh, d_sh),
                          out_shardings=(p_sh, d_sh)).lower(
            p_sds, d_sds, d_sds)
        compiled = lowered.compile()
    coll = parse_collectives(compiled.as_text())
    cost = _cost_dict(compiled.cost_analysis())
    res = {"arch": arch, "kind": "fedluck_sync", "rate": rate, "dim": dim_p,
           "status": "ok", "compile_s": round(time.perf_counter() - t0, 1),
           "collectives": coll,
           "flops_per_device": cost.get("flops"),
           "bytes_accessed_per_device": cost.get("bytes accessed")}
    if verbose:
        print(f"[{arch} sync δ={rate}] coll/dev="
              f"{coll['total_bytes']/2**20:.2f}MiB "
              f"{ {k: v for k, v in coll.items() if isinstance(v, dict) and v['count']} }")
    return res


# -------------------------------------------------------------------- driver
def _result_path(arch, shape, mesh_kind):
    return os.path.join(RESULTS_DIR, f"{arch}__{shape}__{mesh_kind}.json")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--sync-step", action="store_true")
    ap.add_argument("--rate", type=float, default=0.01)
    ap.add_argument("--zero3", action="store_true")
    ap.add_argument("--moe-local", action="store_true")
    ap.add_argument("--no-seq-parallel", action="store_true")
    ap.add_argument("--layout", default="tp", choices=["tp", "dp"])
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args(argv)
    os.makedirs(RESULTS_DIR, exist_ok=True)

    if args.sync_step:
        res = run_sync_step(args.arch, rate=args.rate)
        with open(os.path.join(RESULTS_DIR,
                               f"{args.arch}__sync.json"), "w") as f:
            json.dump(res, f, indent=1)
        return

    if args.all:
        from repro.configs import ARCH_IDS
        from repro.configs.base import SHAPES
        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        failures = []
        for arch in ARCH_IDS:
            for shape in SHAPES:
                for mk in meshes:
                    path = _result_path(arch, shape, mk)
                    if os.path.exists(path) and not args.force:
                        continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape, "--mesh", mk]
                    print(f"--- {arch} × {shape} × {mk}", flush=True)
                    r = subprocess.run(cmd, capture_output=True, text=True,
                                       timeout=3600)
                    sys.stdout.write(r.stdout)
                    if r.returncode != 0:
                        failures.append((arch, shape, mk))
                        sys.stderr.write(r.stderr[-3000:])
        print("FAILURES:", failures if failures else "none")
        return

    res = run_cell(args.arch, args.shape, args.mesh, zero3=args.zero3,
                   moe_local=args.moe_local, layout=args.layout,
                   microbatches=args.microbatch, kv_int8=args.kv_int8,
                   seq_parallel=not args.no_seq_parallel, tag=args.tag)
    path = _result_path(args.arch, args.shape, args.mesh)
    if args.tag:
        path = path.replace(".json", f"__{args.tag}.json")
    with open(path, "w") as f:
        json.dump(res, f, indent=1)


if __name__ == "__main__":
    main()
