"""Production mesh builders. Functions, not module constants — importing
this module must never touch jax device state (the dry-run sets
XLA_FLAGS before any jax initialization)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16×16 = 256 chips (data, model).
    Multi-pod: 2×16×16 = 512 chips (pod, data, model) — the `pod` axis is
    the FedLuck aggregation axis (DESIGN.md §2)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many devices exist (tests / CPU runs)."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


def batch_axes_for(mesh) -> tuple[str, ...]:
    """Batch shards over pod+data when the pod axis exists."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
