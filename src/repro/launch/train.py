"""Training driver.

Two modes:

1. `--mode fl` (default — the paper's setting): asynchronous federated
   training of one of the paper's tasks under any of the 5 methods, on the
   event-driven simulator with real JAX compute, with checkpoint/restart
   (global model + residuals + controller plans survive a crash) and
   optional failure injection.

2. `--mode datacenter`: DiLoCo-style multi-"pod" local SGD on an assigned
   architecture's smoke config: each pod runs k local steps (Alg. 1 device
   loop, jitted lax.scan), compresses its pseudo-gradient with EF top-k at
   the controller-chosen δ, and syncs through the sparse aggregation
   collective (Eq. 6). On this CPU container pods are simulated as mesh
   rows of a local mesh; on real hardware the same code runs one process
   per pod.

Examples:
  PYTHONPATH=src python -m repro.launch.train --task cnn_fmnist \
      --method fedluck --rounds 60 --ckpt-dir /tmp/ck --resume
  PYTHONPATH=src python -m repro.launch.train --mode datacenter \
      --arch mamba2-780m --steps 40 --local-k 5 --rate 0.01
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.obs import log


def make_obs(args):
    """(tracer, metrics) from --trace-out/--metrics-out, else (None, None)."""
    tracer = metrics = None
    if getattr(args, "trace_out", ""):
        from repro.obs import Tracer
        tracer = Tracer()
    if getattr(args, "metrics_out", ""):
        from repro.obs import MetricsRegistry
        metrics = MetricsRegistry()
    return tracer, metrics


def export_obs(args, tracer, metrics, extra=None) -> None:
    """Write the trace/metrics artifacts named by the CLI flags."""
    if tracer is not None:
        from repro.obs import PerfettoExporter
        PerfettoExporter().export(tracer, args.trace_out)
        log.status(f"[obs] wrote trace: {args.trace_out} "
                   f"({len(tracer)} events)")
    if metrics is not None:
        metrics.to_json(args.metrics_out, extra=extra)
        log.status(f"[obs] wrote metrics: {args.metrics_out}")


# --------------------------------------------------------------------- FL mode
def fl_ckpt_state(sim) -> dict:
    """FL checkpoint payload: global model + round + per-device EF
    residuals (without the residuals, a resumed error-feedback run silently
    re-drops every deferred coordinate and diverges from the uninterrupted
    run). Residuals come via `residual_snapshot`, which works for both the
    batched (device-resident stack) and sequential (host dict) engines."""
    state = {"w": np.asarray(sim.model.w),
             "round": np.asarray(sim.model.round)}
    ids, stacked = sim.residual_snapshot()
    if len(ids):
        state["residual_ids"] = ids
        state["residuals"] = stacked
    return state


def restore_fl_state(sim, state) -> None:
    sim.model.w = np.asarray(state["w"])
    sim.model.round = int(state["round"])
    if "residuals" in state:
        sim.load_residuals(np.asarray(state["residual_ids"]),
                           np.asarray(state["residuals"]))


def run_fl(args) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.checkpoint import CheckpointManager
    from repro.core import compression as C
    from repro.core.simulator import (AFLSimulator, STRATEGY_FOR_METHOD,
                                      make_heterogeneous_devices, plan_devices)
    from repro.data.partition import dirichlet_partition, iid_partition
    from repro.core.aggregation import SanitizerConfig
    from repro.ft import FailureSchedule, LossyChannel
    from repro.models.small import make_task

    task = make_task(args.task, num_samples=args.samples,
                     test_samples=args.test_samples,
                     batch_size=args.batch_size, noise=args.noise)
    params = task.init_fn(jax.random.PRNGKey(args.seed))
    flat, _ = C.flatten_pytree(params)
    model_bits = int(flat.size) * 32

    profiles = make_heterogeneous_devices(
        args.devices, model_bits, base_alpha=args.base_alpha, seed=args.seed)
    specs = plan_devices(profiles, args.method, args.round_period,
                         k_bounds=(1, args.k_max), fixed_k=args.fixed_k,
                         fixed_delta=args.fixed_delta,
                         error_feedback=args.error_feedback)
    if args.noniid:
        idx = dirichlet_partition(task.dataset.labels, args.devices,
                                  alpha=1.0, seed=args.seed)
    else:
        idx = iid_partition(len(task.dataset), args.devices, seed=args.seed)

    # --failure-rate N sets the per-device crash rate; the legacy
    # --inject-failures switch keeps its historical default of 0.2
    failure = None
    if args.failure_rate > 0 or args.inject_failures:
        failure = FailureSchedule.random(
            args.devices, args.rounds * args.round_period,
            rate_per_device=args.failure_rate or 0.2, seed=args.seed)
    channel = (LossyChannel(loss_prob=args.loss_rate, seed=args.seed)
               if args.loss_rate > 0 else None)
    sanitizer = None
    if args.tau_max is not None or args.clip_norm is not None:
        sanitizer = SanitizerConfig(tau_max=args.tau_max,
                                    clip_norm=args.clip_norm)

    tracer, metrics = make_obs(args)
    sim = AFLSimulator(task, specs, STRATEGY_FOR_METHOD[args.method],
                       round_period=args.round_period, eta_l=args.eta_l,
                       eta_g=args.eta_g, seed=args.seed, client_indices=idx,
                       failure_schedule=failure, channel=channel,
                       sanitizer=sanitizer, tracer=tracer, metrics=metrics)

    mgr = CheckpointManager(args.ckpt_dir, max_to_keep=2) \
        if args.ckpt_dir else None
    start_round = 0
    if mgr and args.resume:
        latest = mgr.latest_step()
        if latest is not None:
            state = mgr.restore(latest)
            restore_fl_state(sim, state)
            start_round = int(state["round"])
            log.status(f"[train] resumed from round {start_round}")

    # run in checkpointed segments so a crash loses at most one segment
    seg = max(1, args.ckpt_every)
    hist_all = []
    t0 = time.perf_counter()
    while sim.model.round < args.rounds:
        target = min(args.rounds, sim.model.round + seg)
        hist = sim.run(total_rounds=target, eval_every=args.eval_every)
        hist_all.extend(hist.records)
        if mgr:
            mgr.save(sim.model.round, fl_ckpt_state(sim))
            mgr.wait()
        r = hist.records[-1]
        log.status(f"[train] round={sim.model.round} acc={r.accuracy:.3f} "
                   f"sim_t={r.time:.1f}s comm={r.gbits:.3f}Gb "
                   f"wall={time.perf_counter()-t0:.0f}s")
    if not hist_all:
        # resumed at/past the target round: nothing to train, just eval
        hist_all.extend(
            sim.run(total_rounds=sim.model.round, eval_every=1).records)
    final = hist_all[-1]
    export_obs(args, tracer, metrics,
               extra={"engine": "batched" if sim._batched else "sequential",
                      "task": args.task, "method": args.method})
    return {"final_accuracy": final.accuracy, "rounds": sim.model.round,
            "gbits": final.gbits, "sim_time": final.time,
            "fault_counters": sim.fault_counters()}


# ------------------------------------------------------------- datacenter mode
def run_datacenter(args) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core import compression as C
    from repro.core.controller import DeviceProfile, FedLuckController
    from repro.data.synthetic import SyntheticTokens
    from repro.dist.steps import make_local_round_step
    from repro.models.transformer import LM
    from repro.optim import momentum_sgd
    from repro.checkpoint import CheckpointManager

    cfg = get_config(args.arch).smoke()
    lm = LM(cfg, dtype=jnp.float32, remat=False)
    opt = momentum_sgd(args.eta_l, momentum=0.9)
    n_pods = args.pods

    # ---- controller picks (k, δ) per pod from measured α and link β
    ctl = FedLuckController(round_period=args.round_period,
                            k_bounds=(1, args.local_k_max),
                            delta_bounds=(1e-3, 1.0))
    dim_probe = None

    params = [lm.init(jax.random.PRNGKey(args.seed)) for _ in range(n_pods)]
    opt_states = [opt.init(p) for p in params]
    flat0, spec0 = C.flatten_pytree(params[0])
    dim = int(flat0.size)
    residuals = [np.zeros((dim,), np.float32) for _ in range(n_pods)]

    if cfg.frontend != "tokens":
        raise SystemExit("datacenter demo supports token LMs")
    ds = SyntheticTokens(vocab=cfg.vocab, seq_len=65, num_samples=2048)

    local_round = {}
    # measure α on pod 0, derive β from a nominal 100 Gb/s DCN link
    def batches_for(k, rng):
        idx = rng.randint(0, len(ds), size=(k, args.batch_size))
        bs = [ds.batch(i) for i in idx]
        return {kk: np.stack([b[kk] for b in bs]) for kk in bs[0]}

    rng = np.random.RandomState(args.seed)
    probe = jax.jit(make_local_round_step(lm, opt, 2))
    t0 = time.perf_counter()
    probe(params[0], opt_states[0], batches_for(2, rng))
    t1 = time.perf_counter()
    out = probe(params[0], opt_states[0], batches_for(2, rng))
    jax.block_until_ready(out[3])
    alpha = (time.perf_counter() - t1) / 2
    beta = dim * 32 / args.dcn_bps
    plans = [ctl.register(DeviceProfile(i, alpha * (1 + 0.5 * i), beta))
             for i in range(n_pods)]
    log.status("[datacenter] plans:")
    log.status(ctl.summary())

    mgr = CheckpointManager(args.ckpt_dir, max_to_keep=2) \
        if args.ckpt_dir else None

    comm_bits = 0.0
    t0 = time.perf_counter()
    for step in range(args.steps):
        deltas = []
        losses = []
        for i in range(n_pods):
            k = plans[i].k if not args.local_k else args.local_k
            if k not in local_round:
                local_round[k] = jax.jit(make_local_round_step(lm, opt, k))
            p1, o1, delta, loss = local_round[k](
                params[i], opt_states[i], batches_for(k, rng))
            flat_d, _ = C.flatten_pytree(delta)
            rate = plans[i].delta if not args.rate else args.rate
            comp, residuals[i] = C.ef_compress(
                C.make_compressor("topk", rate), np.asarray(flat_d),
                residuals[i])
            deltas.append(np.asarray(comp.dense()))
            # payload-shape accounting: value/index bits + kept-count
            # header, matching the compact pod-sync wire format
            comm_bits += float(C.payload_bits(comp))
            opt_states[i] = o1
            losses.append(float(loss))
        # Eq. 6 aggregation (the sparse all-reduce in the real deployment)
        agg = np.mean(deltas, axis=0)
        flat_w, specw = C.flatten_pytree(params[0])
        new_flat = np.asarray(flat_w) - args.eta_g * agg
        new_params = C.unflatten_pytree(jnp.asarray(new_flat), specw)
        params = [new_params for _ in range(n_pods)]
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, {"w": new_flat})
            mgr.wait()
        if step % 5 == 0 or step == args.steps - 1:
            log.status(f"[datacenter] round={step} "
                       f"loss={np.mean(losses):.4f} "
                       f"comm={comm_bits/8e6:.1f}MB "
                       f"wall={time.perf_counter()-t0:.0f}s")
    return {"loss": float(np.mean(losses)), "comm_mb": comm_bits / 8e6}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="fl", choices=["fl", "datacenter"])
    # fl
    ap.add_argument("--task", default="cnn_fmnist")
    ap.add_argument("--method", default="fedluck")
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--devices", type=int, default=10)
    ap.add_argument("--round-period", type=float, default=1.0)
    ap.add_argument("--k-max", type=int, default=30)
    ap.add_argument("--fixed-k", type=int, default=10)
    ap.add_argument("--fixed-delta", type=float, default=0.1)
    ap.add_argument("--eta-l", type=float, default=0.05)
    ap.add_argument("--eta-g", type=float, default=1.0)
    ap.add_argument("--base-alpha", type=float, default=0.02)
    ap.add_argument("--samples", type=int, default=4000)
    ap.add_argument("--test-samples", type=int, default=800)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--noise", type=float, default=None)
    ap.add_argument("--error-feedback", action="store_true")
    ap.add_argument("--noniid", action="store_true")
    ap.add_argument("--inject-failures", action="store_true")
    ap.add_argument("--failure-rate", type=float, default=0.0,
                    help="mean crash windows per device over the run "
                         "(FailureSchedule.random rate_per_device)")
    ap.add_argument("--loss-rate", type=float, default=0.0,
                    help="per-attempt upload loss probability (LossyChannel "
                         "with default retry/backoff policy)")
    ap.add_argument("--tau-max", type=int, default=None,
                    help="staleness cap: aggregation drops updates with "
                         "τ > tau-max (enables the UpdateSanitizer)")
    ap.add_argument("--clip-norm", type=float, default=None,
                    help="L2 norm outlier guard on admitted updates "
                         "(enables the UpdateSanitizer)")
    ap.add_argument("--eval-every", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    # datacenter
    ap.add_argument("--arch", default="mamba2-780m")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--local-k", type=int, default=0)
    ap.add_argument("--local-k-max", type=int, default=10)
    ap.add_argument("--rate", type=float, default=0.0)
    ap.add_argument("--dcn-bps", type=float, default=100e9)
    # observability (fl mode)
    ap.add_argument("--trace-out", default="",
                    help="write a Perfetto/Chrome trace JSON of the run "
                         "(open at ui.perfetto.dev)")
    ap.add_argument("--metrics-out", default="",
                    help="write a metrics snapshot JSON "
                         "(repro.obs.MetricsRegistry)")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress status lines (final JSON still printed)")
    args = ap.parse_args(argv)
    log.set_quiet(args.quiet)

    res = run_fl(args) if args.mode == "fl" else run_datacenter(args)
    print(json.dumps(res, indent=1))


if __name__ == "__main__":
    main()
