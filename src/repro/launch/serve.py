"""Batched serving driver: prefill + decode loop for any token-LM arch.

Runs the smoke config on CPU (the full configs are exercised via the
dry-run). Demonstrates the serving substrate: batched prefill, KV/SSM
cache management, greedy decode with per-slot stop, and simple continuous
batching (a finished slot is refilled from the request queue at the next
step boundary).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b \
      --requests 6 --batch 2 --prompt-len 16 --gen 24
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.obs import log


def main(argv=None):
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models.transformer import LM

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quiet", action="store_true",
                    help="suppress status lines (final JSON still printed)")
    args = ap.parse_args(argv)
    log.set_quiet(args.quiet)

    cfg = get_config(args.arch).smoke()
    if cfg.frontend == "frames":
        raise SystemExit("encoder-only arch has no decode path")
    lm = LM(cfg, dtype=jnp.float32, remat=False)
    params = lm.init(jax.random.PRNGKey(args.seed))

    B, P, G = args.batch, args.prompt_len, args.gen
    S_max = P + G + (cfg.n_patches if cfg.frontend == "patches" else 0)
    rng = np.random.RandomState(args.seed)
    queue = [rng.randint(0, cfg.vocab, size=(P,)).astype(np.int32)
             for _ in range(args.requests)]

    prefill = jax.jit(lm.prefill)
    decode = jax.jit(lm.decode_step)

    served, t0 = [], time.perf_counter()
    while queue:
        prompts = [queue.pop(0) for _ in range(min(B, len(queue)))]
        while len(prompts) < B:                   # pad the last batch
            prompts.append(prompts[-1])
        toks = jnp.asarray(np.stack(prompts))
        if cfg.frontend == "patches":
            batch = {"patches": jnp.zeros((B, cfg.n_patches, cfg.patch_dim),
                                          jnp.float32),
                     "tokens": toks}
            base = cfg.n_patches + P
        else:
            batch = {"tokens": toks}
            base = P
        logits, cache = prefill(params, batch)
        # grow the KV cache [L, B, S, KV, hd] to S_max along the S axis
        cache = {k: (jnp.concatenate(
            [v, jnp.zeros(v.shape[:2] + (S_max - v.shape[2],) + v.shape[3:],
                          v.dtype)], axis=2) if k in ("k", "v") else v)
            for k, v in cache.items()}
        out = np.zeros((B, G), np.int32)
        tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
        for g in range(G):
            out[:, g] = np.asarray(tok[:, 0])
            logits, cache = decode(params, cache, tok, jnp.int32(base + g))
            tok = jnp.argmax(logits[:, 0, :], -1).astype(jnp.int32)[:, None]
        for row in out:
            served.append(row.tolist())
        log.status(f"[serve] batch done: {len(served)}/{args.requests} "
                   f"t={time.perf_counter()-t0:.1f}s")

    tput = args.requests * G / (time.perf_counter() - t0)
    print(json.dumps({"arch": args.arch, "requests": args.requests,
                      "tokens_per_s": round(tput, 1),
                      "sample": served[0][:8]}))


if __name__ == "__main__":
    main()
