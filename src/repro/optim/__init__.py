from repro.optim.optim import (
    Optimizer, momentum_sgd, adamw, sgd, apply_updates,
    cosine_schedule, constant_schedule, warmup_cosine,
)

__all__ = [
    "Optimizer", "momentum_sgd", "adamw", "sgd", "apply_updates",
    "cosine_schedule", "constant_schedule", "warmup_cosine",
]
