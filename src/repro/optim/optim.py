"""Optimizers (optax is not installed offline; same (init, update) protocol).

All optimizers are pytree-polymorphic and jit-safe. `momentum_sgd` is the
paper's setting (momentum 0.9). AdamW carries fp32 master weights when the
params are low-precision (the large-arch policy).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Schedule = Callable[[jax.Array], jax.Array]


def constant_schedule(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(lr: float, total_steps: int, final_frac: float = 0.1) -> Schedule:
    def f(step):
        t = jnp.minimum(step.astype(jnp.float32) / max(1, total_steps), 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return lr * (final_frac + (1 - final_frac) * cos)
    return f


def warmup_cosine(lr: float, warmup: int, total_steps: int,
                  final_frac: float = 0.1) -> Schedule:
    cos = cosine_schedule(lr, max(1, total_steps - warmup), final_frac)

    def f(step):
        step = step.astype(jnp.float32)
        warm = lr * step / max(1, warmup)
        return jnp.where(step < warmup, warm, cos(step - warmup))
    return f


@dataclasses.dataclass(frozen=True)
class Optimizer:
    """(init, update) pair. update returns (new_params, new_state)."""
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]  # (grads, state, params)
    name: str = "opt"


def _cast_like(src, ref):
    return jax.tree.map(lambda s, r: s.astype(r.dtype), src, ref)


def sgd(lr: float | Schedule) -> Optimizer:
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        eta = sched(state["step"])
        new_params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - eta * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new_params, {"step": state["step"] + 1}

    return Optimizer(init, update, "sgd")


def momentum_sgd(lr: float | Schedule, momentum: float = 0.9,
                 weight_decay: float = 0.0, nesterov: bool = False) -> Optimizer:
    """Paper's optimizer: momentum-SGD, momentum 0.9 (Sec 4.3)."""
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }

    def update(grads, state, params):
        eta = sched(state["step"])

        def upd(p, g, m):
            g32 = g.astype(jnp.float32)
            if weight_decay:
                g32 = g32 + weight_decay * p.astype(jnp.float32)
            m_new = momentum * m + g32
            step_dir = (g32 + momentum * m_new) if nesterov else m_new
            return (p.astype(jnp.float32) - eta * step_dir).astype(p.dtype), m_new

        flat = jax.tree.map(upd, params, grads, state["mu"])
        new_params = jax.tree.map(lambda t: t[0], flat,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree.map(lambda t: t[1], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
        return new_params, {"step": state["step"] + 1, "mu": new_mu}

    return Optimizer(init, update, "momentum_sgd")


def adamw(lr: float | Schedule, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(z, params),
            "v": jax.tree.map(z, params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        eta = sched(state["step"])
        b1t = 1 - b1 ** step.astype(jnp.float32)
        b2t = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * g32
            v_new = b2 * v + (1 - b2) * jnp.square(g32)
            mhat = m_new / b1t
            vhat = v_new / b2t
            delta = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                delta = delta + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - eta * delta).astype(p.dtype), m_new, v_new

        flat = jax.tree.map(upd, params, grads, state["m"], state["v"])
        is_t = lambda t: isinstance(t, tuple)
        new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=is_t)
        new_m = jax.tree.map(lambda t: t[1], flat, is_leaf=is_t)
        new_v = jax.tree.map(lambda t: t[2], flat, is_leaf=is_t)
        return new_params, {"step": step, "m": new_m, "v": new_v}

    return Optimizer(init, update, "adamw")


def apply_updates(params, updates, scale: float = 1.0):
    """params + scale * updates (used by the PS-side global update, Eq. 6)."""
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32)
                      + scale * u.astype(jnp.float32)).astype(p.dtype),
        params, updates)
