from repro.data.synthetic import (
    SyntheticClassification, SyntheticTokens, SyntheticSpeech, make_task_dataset,
)
from repro.data.partition import dirichlet_partition, iid_partition
from repro.data.pipeline import DataLoader, sharded_batches

__all__ = [
    "SyntheticClassification", "SyntheticTokens", "SyntheticSpeech",
    "make_task_dataset", "dirichlet_partition", "iid_partition",
    "DataLoader", "sharded_batches",
]
