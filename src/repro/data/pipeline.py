"""Batching pipeline: per-client infinite loaders + mesh-sharded host batches."""
from __future__ import annotations

from typing import Iterator

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class DataLoader:
    """Infinite shuffled batches over a subset of a dataset (one FL client)."""

    def __init__(self, dataset, indices: np.ndarray | None = None,
                 batch_size: int = 64, seed: int = 0, drop_last: bool = True):
        self.ds = dataset
        self.indices = np.arange(len(dataset)) if indices is None else indices
        self.batch_size = min(batch_size, len(self.indices))
        self.rng = np.random.RandomState(seed)
        self._order = self.rng.permutation(self.indices)
        self._pos = 0

    def next(self) -> dict:
        if self._pos + self.batch_size > len(self._order):
            self._order = self.rng.permutation(self.indices)
            self._pos = 0
        idx = self._order[self._pos:self._pos + self.batch_size]
        self._pos += self.batch_size
        return self.ds.batch(idx)

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next()


def sharded_batches(loader: DataLoader, mesh: Mesh,
                    batch_axes: tuple[str, ...] = ("data",)) -> Iterator[dict]:
    """Place host batches on the mesh, batch dim sharded over `batch_axes`."""
    spec = P(batch_axes)
    while True:
        host = loader.next()
        yield {
            k: jax.device_put(v, NamedSharding(mesh, spec if np.ndim(v) else P()))
            for k, v in host.items()
        }
