"""Batching pipeline: per-client infinite loaders, stacked-batch prefetch,
and mesh-sharded host batches."""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class DataLoader:
    """Infinite shuffled batches over a subset of a dataset (one FL client)."""

    def __init__(self, dataset, indices: np.ndarray | None = None,
                 batch_size: int = 64, seed: int = 0, drop_last: bool = True):
        self.ds = dataset
        self.indices = np.arange(len(dataset)) if indices is None else indices
        self.batch_size = min(batch_size, len(self.indices))
        self.rng = np.random.RandomState(seed)
        self._order = self.rng.permutation(self.indices)
        self._pos = 0

    def next(self) -> dict:
        if self._pos + self.batch_size > len(self._order):
            self._order = self.rng.permutation(self.indices)
            self._pos = 0
        idx = self._order[self._pos:self._pos + self.batch_size]
        self._pos += self.batch_size
        return self.ds.batch(idx)

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next()


class StackedLoader:
    """Stacked-batch iterator over a `DataLoader` for k-step local rounds.

    Each `next()` groups `k` consecutive loader batches into one host batch
    of shape [k, B, ...] — the layout `lax.scan`-based local rounds consume.
    With `prefetch > 0` a background thread draws *individual* loader
    batches ahead into a bounded queue and `next()` stacks `k` of them,
    overlapping host-side batching with device compute. The queue holds
    per-step batches, not stacked rounds, so draws are k-agnostic: a
    mid-run `set_k` (controller re-plan) only changes how many are popped
    per round, and the underlying draw sequence — hence every batch a run
    sees — is bitwise identical to `prefetch=0`, re-plans included (the
    single producer preserves the loader's RNG order).
    """

    def __init__(self, loader: DataLoader, k: int, prefetch: int = 1):
        self.loader = loader
        self.k = int(k)
        self._depth = int(prefetch)
        self._q: queue.Queue | None = None
        self._thread: threading.Thread | None = None
        self._stop = False

    def set_k(self, k: int) -> None:
        """Adopt a new local-round length from the next `next()` on.
        Prefetched per-step batches stay valid — nothing is flushed."""
        self.k = int(k)

    def _next_batch(self) -> dict:
        if self._depth <= 0:
            return self.loader.next()
        if self._thread is None:
            # depth is in units of stacked rounds at the initial k
            self._q = queue.Queue(maxsize=max(2, self._depth * self.k))
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()
        return self._q.get()

    def _worker(self) -> None:
        while not self._stop:
            item = self.loader.next()
            while not self._stop:
                try:
                    self._q.put(item, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def next(self) -> dict:
        batches = [self._next_batch() for _ in range(self.k)]
        return {kk: np.stack([b[kk] for b in batches]) for kk in batches[0]}

    def close(self) -> None:
        """Stop the prefetch thread (safe to call more than once)."""
        self._stop = True
        if self._q is not None:
            try:
                self._q.get_nowait()
            except queue.Empty:
                pass

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next()


def sharded_batches(loader: DataLoader, mesh: Mesh,
                    batch_axes: tuple[str, ...] = ("data",)) -> Iterator[dict]:
    """Place host batches on the mesh, batch dim sharded over `batch_axes`."""
    spec = P(batch_axes)
    while True:
        host = loader.next()
        yield {
            k: jax.device_put(v, NamedSharding(mesh, spec if np.ndim(v) else P()))
            for k, v in host.items()
        }
