"""Synthetic stand-ins for the paper's datasets (offline container).

The paper trains CNN@FMNIST (28x28x1, 10 classes), VGG11s@CIFAR-10
(32x32x3, 10 classes) and LSTM@SC (speech commands: 1s audio -> MFCC
frames, 10-35 classes). No datasets ship offline, so we generate
learnable synthetic tasks with the same shapes and difficulty knobs:
class-prototype + structured noise. Accuracy-vs-time *ratios between
methods* (what the paper reports) are preserved because every method
trains on the identical stream.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticClassification:
    """Images drawn as class prototype + low-rank distortion + pixel noise."""
    num_classes: int = 10
    shape: tuple = (28, 28, 1)   # FMNIST-like; (32,32,3) for CIFAR-like
    num_samples: int = 10_000
    noise: float = 0.35          # per-pixel noise std
    signal: float = 4.0          # prototype norm (class-signal strength)
    seed: int = 0                # fixes the task (prototypes + mixing)
    sample_seed: int = 0         # fixes the draw (train vs test split)

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)          # task randomness
        srng = np.random.RandomState(self.sample_seed + 104729)  # draw
        d = int(np.prod(self.shape))
        self.prototypes = rng.randn(self.num_classes, d).astype(np.float32)
        self.prototypes *= self.signal / np.linalg.norm(
            self.prototypes, axis=1, keepdims=True)
        self.mix = rng.randn(8, d).astype(np.float32) / np.sqrt(d)
        self.labels = srng.randint(0, self.num_classes, self.num_samples)
        coeff = srng.randn(self.num_samples, 8).astype(np.float32)
        noise = srng.randn(self.num_samples, d).astype(np.float32) * self.noise
        x = self.prototypes[self.labels] + coeff @ self.mix * 0.5 + noise
        self.images = x.reshape((self.num_samples,) + self.shape)

    def __len__(self):
        return self.num_samples

    def batch(self, idx: np.ndarray):
        return {"image": self.images[idx], "label": self.labels[idx]}


@dataclasses.dataclass
class SyntheticSpeech:
    """SC-like: [T, F] MFCC-ish frames, class = prototype trajectory."""
    num_classes: int = 10
    seq_len: int = 49
    features: int = 40
    num_samples: int = 8_000
    noise: float = 0.4
    signal: float = 0.5          # per-element prototype scale
    seed: int = 1                # fixes the task (prototypes)
    sample_seed: int = 0         # fixes the draw (train vs test split)

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        srng = np.random.RandomState(self.sample_seed + 104729)
        self.proto = rng.randn(self.num_classes, self.seq_len,
                               self.features).astype(np.float32)
        self.proto *= self.signal
        self.labels = srng.randint(0, self.num_classes, self.num_samples)
        noise = srng.randn(self.num_samples, self.seq_len,
                          self.features).astype(np.float32) * self.noise
        self.frames = self.proto[self.labels] + noise

    def __len__(self):
        return self.num_samples

    def batch(self, idx: np.ndarray):
        return {"frames": self.frames[idx], "label": self.labels[idx]}


@dataclasses.dataclass
class SyntheticTokens:
    """LM token stream with Zipfian unigram + short-range bigram structure."""
    vocab: int = 32_000
    seq_len: int = 128
    num_samples: int = 4_096
    seed: int = 2

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        ranks = np.arange(1, self.vocab + 1)
        p = 1.0 / ranks ** 1.1
        p /= p.sum()
        flat = rng.choice(self.vocab, size=self.num_samples * self.seq_len, p=p)
        # inject copy structure: token[t] = token[t-8] with prob .25
        flat = flat.reshape(self.num_samples, self.seq_len)
        for t in range(8, self.seq_len):
            m = rng.rand(self.num_samples) < 0.25
            flat[m, t] = flat[m, t - 8]
        self.tokens = flat.astype(np.int32)

    def __len__(self):
        return self.num_samples

    def batch(self, idx: np.ndarray):
        tok = self.tokens[idx]
        return {"tokens": tok[:, :-1], "labels": tok[:, 1:]}


def make_task_dataset(task: str, **kw):
    """Factory matching the paper's three tasks."""
    if task in ("fmnist", "cnn_fmnist"):
        return SyntheticClassification(shape=(28, 28, 1), **kw)
    if task in ("cifar10", "vgg11s_cifar10"):
        return SyntheticClassification(shape=(32, 32, 3), **kw)
    if task in ("sc", "lstm_sc"):
        return SyntheticSpeech(**kw)
    if task == "lm":
        return SyntheticTokens(**kw)
    raise ValueError(f"unknown task {task}")
