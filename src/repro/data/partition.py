"""Client data partitioners: IID and Dirichlet non-IID (paper Sec 4.3, α=1.0)."""
from __future__ import annotations

import numpy as np


def iid_partition(num_samples: int, num_clients: int, seed: int = 0) -> list[np.ndarray]:
    rng = np.random.RandomState(seed)
    perm = rng.permutation(num_samples)
    return [np.sort(s) for s in np.array_split(perm, num_clients)]


def dirichlet_partition(labels: np.ndarray, num_clients: int,
                        alpha: float = 1.0, seed: int = 0,
                        min_per_client: int = 8) -> list[np.ndarray]:
    """Assign samples to clients with per-class Dirichlet(alpha) proportions.

    Matches Hsu et al. 2019 as cited by the paper (concentration α=1.0).
    Retries until every client has at least `min_per_client` samples.
    """
    rng = np.random.RandomState(seed)
    classes = np.unique(labels)
    for _attempt in range(100):
        buckets: list[list[int]] = [[] for _ in range(num_clients)]
        for c in classes:
            idx = np.where(labels == c)[0]
            rng.shuffle(idx)
            props = rng.dirichlet([alpha] * num_clients)
            cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
            for b, part in zip(buckets, np.split(idx, cuts)):
                b.extend(part.tolist())
        sizes = [len(b) for b in buckets]
        if min(sizes) >= min_per_client:
            return [np.sort(np.asarray(b)) for b in buckets]
    raise RuntimeError("dirichlet_partition failed to satisfy min_per_client")
