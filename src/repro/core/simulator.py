"""Event-driven AFL simulator with a simulated wall clock (paper Sec 4.3).

Real JAX training, simulated time: each device runs its k_i local
momentum-SGD steps as one jitted `lax.scan`, compresses the pseudo-gradient
(Eq. 4) with its δ_i, and "uploads" — the upload lands on the simulated
clock at  t + k_i·α_i + rate_i·β_i  (Eq. 5). The server strategy decides
when aggregation happens (periodic / buffered / async / sync) and the
simulator hands fresh global models back to devices.

Communication accounting follows the paper: transmitted data ∝ δ
(bits = rate·d·32, time = rate·β). Strict values/indices accounting is
available via `count_index_bits=True`.

Fault tolerance hooks: a `FailureSchedule` (repro.ft) injects device
crashes — an in-flight upload inside a failure window is lost, and the
device re-registers at recovery (elastic membership; the FedLuck controller
re-plans). Stragglers are devices whose α drifts mid-run.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression as C
from repro.core.aggregation import (Arrival, GlobalModel, PeriodicAggregator,
                                    SyncAggregator, make_aggregator)
from repro.core.controller import DeviceProfile, FedLuckController
from repro.core.factor import Plan


# ----------------------------------------------------------------------- task
@dataclasses.dataclass
class TrainTask:
    """A trainable model + data, in pure-function form."""
    name: str
    init_fn: Callable[[jax.Array], Any]              # rng -> params pytree
    loss_fn: Callable[[Any, dict], jax.Array]        # (params, batch) -> scalar
    acc_fn: Callable[[Any, dict], jax.Array]         # (params, batch) -> scalar
    dataset: Any                                     # train split (repro.data)
    test_batch: dict                                 # held-out eval batch
    batch_size: int = 64


@dataclasses.dataclass
class DeviceSpec:
    """Static per-device simulation knobs."""
    profile: DeviceProfile
    plan: Plan
    compressor: str = "topk"      # topk | randk | qsgd | signsgd | none
    error_feedback: bool = False

    @property
    def rate(self) -> float:
        """Effective wire rate (fraction of a full fp32 gradient)."""
        if self.compressor in ("topk", "topk_threshold", "randk"):
            return self.plan.delta
        if self.compressor == "qsgd":
            return 9.0 / 32.0
        if self.compressor == "signsgd":
            return 1.0 / 32.0
        return 1.0


@dataclasses.dataclass
class Record:
    time: float
    round: int
    accuracy: float
    loss: float
    gbits: float
    mean_staleness: float


@dataclasses.dataclass
class History:
    records: list[Record] = dataclasses.field(default_factory=list)

    def time_to_accuracy(self, target: float) -> float | None:
        for r in self.records:
            if r.accuracy >= target:
                return r.time
        return None

    def bits_to_accuracy(self, target: float) -> float | None:
        for r in self.records:
            if r.accuracy >= target:
                return r.gbits
        return None

    def final_accuracy(self, window: int = 3) -> float:
        if not self.records:
            return 0.0
        return float(np.mean([r.accuracy for r in self.records[-window:]]))


# ------------------------------------------------------------------ simulator
class AFLSimulator:
    def __init__(self, task: TrainTask, devices: list[DeviceSpec],
                 strategy: str = "periodic", *, round_period: float = 1.0,
                 eta_l: float = 0.05, eta_g: float = 1.0,
                 momentum: float = 0.9, seed: int = 0,
                 client_indices: list[np.ndarray] | None = None,
                 failure_schedule=None, count_index_bits: bool = False,
                 strategy_kwargs: dict | None = None):
        self.task = task
        self.devices = {d.profile.device_id: d for d in devices}
        self.round_period = float(round_period)
        self.eta_l, self.eta_g, self.momentum = eta_l, eta_g, momentum
        self.failure_schedule = failure_schedule
        self.count_index_bits = count_index_bits
        self.strategy_name = strategy
        self.rng = np.random.RandomState(seed)

        # ---- params / flat spec
        params = task.init_fn(jax.random.PRNGKey(seed))
        flat, self.spec = C.flatten_pytree(params)
        self.dim = int(flat.shape[0])
        self.model = GlobalModel(np.asarray(flat), eta_g=eta_g)
        skw = dict(strategy_kwargs or {})
        if strategy in ("sync", "fedavg", "fedavg_topk"):
            skw.setdefault("num_devices", len(devices))
        self.agg = make_aggregator(strategy, self.model, **skw)

        # ---- per-client data
        from repro.data.pipeline import DataLoader
        n = len(task.dataset)
        if client_indices is None:
            from repro.data.partition import iid_partition
            client_indices = iid_partition(n, len(devices), seed=seed)
        self.loaders = {
            did: DataLoader(task.dataset, idx, batch_size=task.batch_size,
                            seed=seed + 17 * did)
            for did, idx in zip(sorted(self.devices), client_indices)}

        # ---- jitted compute, cached per static k / rate
        self._round_fns: dict[int, Callable] = {}
        self._compress_fns: dict[tuple, Callable] = {}
        self._residuals: dict[int, np.ndarray] = {
            did: np.zeros((self.dim,), np.float32) for did in self.devices}
        self._eval_fn = jax.jit(self._make_eval())

    # --------------------------------------------------------------- jit fns
    def _make_eval(self):
        loss_fn, acc_fn, spec = self.task.loss_fn, self.task.acc_fn, self.spec

        def ev(flat, batch):
            params = C.unflatten_pytree(flat, spec)
            return acc_fn(params, batch), loss_fn(params, batch)
        return ev

    def _local_round_fn(self, k: int):
        """flat params + stacked batches[k] -> pseudo-gradient g = w0 - wk."""
        if k in self._round_fns:
            return self._round_fns[k]
        loss_fn, spec = self.task.loss_fn, self.spec
        eta_l, mom = self.eta_l, self.momentum

        @jax.jit
        def run(flat, batches):
            params = C.unflatten_pytree(flat, spec)
            mu0 = jax.tree.map(jnp.zeros_like, params)

            def step(carry, batch):
                p, mu = carry
                g = jax.grad(loss_fn)(p, batch)
                mu = jax.tree.map(lambda m, gg: mom * m + gg, mu, g)
                p = jax.tree.map(lambda pp, m: pp - eta_l * m, p, mu)
                return (p, mu), None

            (p1, _), _ = jax.lax.scan(step, (params, mu0), batches)
            f1, _ = C.flatten_pytree(p1)
            return flat - f1  # Eq. 4

        self._round_fns[k] = run
        return run

    def _compressor_fn(self, spec_d: DeviceSpec):
        key = (spec_d.compressor, round(spec_d.plan.delta, 6),
               spec_d.error_feedback)
        if key in self._compress_fns:
            return self._compress_fns[key]
        comp = C.make_compressor(spec_d.compressor, spec_d.plan.delta)

        @jax.jit
        def run(g, residual, rngkey):
            cc, new_res = C.ef_compress(comp, g, residual, rngkey)
            return cc.dense(), new_res, cc.wire_bits

        @jax.jit
        def run_noef(g, rngkey):
            cc = comp(g, rngkey)
            return cc.dense(), cc.wire_bits

        fn = run if spec_d.error_feedback else run_noef
        self._compress_fns[key] = fn
        return fn

    # ----------------------------------------------------------- device cycle
    def _device_cycle(self, did: int, start_time: float, model_round: int,
                      flat_model: np.ndarray):
        """Compute one local round; return the Arrival (or None if the device
        fails mid-cycle per the failure schedule)."""
        spec = self.devices[did]
        k = spec.plan.k
        loader = self.loaders[did]
        batches = [loader.next() for _ in range(k)]
        stacked = {kk: np.stack([b[kk] for b in batches]) for kk in batches[0]}
        g = self._local_round_fn(k)(jnp.asarray(flat_model), stacked)

        rngkey = jax.random.PRNGKey(self.rng.randint(0, 2 ** 31 - 1))
        if spec.error_feedback:
            dense, new_res, strict_bits = self._compressor_fn(spec)(
                g, jnp.asarray(self._residuals[did]), rngkey)
            self._residuals[did] = np.asarray(new_res)
        else:
            dense, strict_bits = self._compressor_fn(spec)(g, rngkey)

        compute_t = k * spec.profile.alpha
        tx_t = spec.rate * spec.profile.beta
        finish = start_time + compute_t + tx_t
        if self.failure_schedule is not None and \
                self.failure_schedule.lost_in_flight(did, start_time, finish):
            return None, self.failure_schedule.recovery_time(did, start_time)
        bits = (float(strict_bits) if self.count_index_bits
                else spec.rate * self.dim * 32.0)
        return Arrival(did, np.asarray(dense), model_round, bits, finish), None

    # -------------------------------------------------------------------- run
    def run(self, total_rounds: int = 50, eval_every: int = 1,
            max_sim_time: float = math.inf) -> History:
        hist = History()
        heap: list = []
        seq = 0

        def push(t, kind, payload):
            nonlocal seq
            heapq.heappush(heap, (t, seq, kind, payload))
            seq += 1

        periodic = isinstance(self.agg, PeriodicAggregator)
        syncb = isinstance(self.agg, SyncAggregator)
        if syncb:
            self.agg.begin_round(0.0, list(self.devices))

        # kick off every device at t=0 with the initial model
        for did in self.devices:
            push(0.0, "start", (did, self.model.round))
        if periodic:
            push(self.round_period, "boundary", 1)

        evals_done = 0
        last_t = 0.0
        while heap:
            t, _, kind, payload = heapq.heappop(heap)
            if t > max_sim_time or self.model.round >= total_rounds:
                break
            last_t = t

            if kind == "start":
                did, mr = payload
                if self.failure_schedule is not None and \
                        self.failure_schedule.is_down(did, t):
                    push(self.failure_schedule.recovery_time(did, t), "start",
                         (did, self.model.round))
                    continue
                arrival, retry_at = self._device_cycle(
                    did, t, mr, self.model.w)
                if arrival is None:  # crashed mid-cycle: lost update
                    push(retry_at, "start", (did, self.model.round))
                else:
                    push(arrival.arrive_time, "arrival", arrival)

            elif kind == "arrival":
                a: Arrival = payload
                events = self.agg.on_arrival(t, a)
                for ev in events:
                    for did in ev.release_to:
                        push(ev.time, "start", (did, self.model.round))
                    if syncb and ev.release_to:
                        self.agg.begin_round(ev.time, list(self.devices))
                if not events and not periodic and not syncb:
                    # buffered strategy: device waits; FedBuff hands the
                    # *current* model back immediately so training continues
                    push(t, "start", (a.device_id, self.model.round))
                if events and eval_every and \
                        self.model.round >= evals_done * eval_every:
                    self._eval(hist, t)
                    evals_done += 1

            elif kind == "boundary":
                r = payload
                events = self.agg.on_round_boundary(t)
                for ev in events:
                    for did in ev.release_to:
                        push(ev.time, "start", (did, self.model.round))
                push(t + self.round_period, "boundary", r + 1)
                if eval_every and self.model.round >= evals_done * eval_every:
                    self._eval(hist, t)
                    evals_done += 1

        # closing record: the break-event time when we stopped early, else
        # the LAST PROCESSED event time — never max_sim_time, which is inf
        # by default and would poison History.time_to_accuracy.
        self._eval(hist, t if heap else last_t)
        return hist

    def _eval(self, hist: History, t: float):
        acc, loss = self._eval_fn(jnp.asarray(self.model.w),
                                  self.task.test_batch)
        stal = self.agg.staleness_log[-len(self.devices):]
        hist.records.append(Record(
            time=float(t), round=int(self.model.round),
            accuracy=float(acc), loss=float(loss),
            gbits=self.agg.total_bits / 1e9,
            mean_staleness=float(np.mean(stal)) if stal else 0.0))


# ------------------------------------------------------------ device builders
def make_heterogeneous_devices(
        num: int, model_bits: float, *, base_alpha: float = 0.02,
        alpha_spread: float = 4.0, bw_range: tuple = (0.25e6, 2e6),
        seed: int = 0) -> list[DeviceProfile]:
    """Paper Sec 4.3: α ~ U[a, 4a]; bandwidth ~ U[0.25, 2] Mb/s."""
    rng = np.random.RandomState(seed)
    out = []
    for i in range(num):
        alpha = rng.uniform(base_alpha, base_alpha * alpha_spread)
        bw = rng.uniform(*bw_range)
        out.append(DeviceProfile.from_bandwidth(i, alpha, model_bits, bw))
    return out


def plan_devices(profiles: list[DeviceProfile], method: str,
                 round_period: float, *, k_bounds=(1, 60),
                 delta_bounds=(1e-3, 1.0), fixed_k: int = 10,
                 fixed_delta: float = 0.1,
                 compressor_override: str | None = None,
                 error_feedback: bool = False) -> list[DeviceSpec]:
    """Build DeviceSpecs for one of the 5 methods of the paper's Sec 4."""
    method = method.lower()
    specs = []
    if method == "fedluck":
        ctl = FedLuckController(round_period, k_bounds, delta_bounds)
        for p in profiles:
            plan = ctl.register(p)
            specs.append(DeviceSpec(p, plan, compressor_override or "topk",
                                    error_feedback))
    elif method == "opt_cr":   # fixed k, optimize δ (Tab. 2)
        ctl = FedLuckController(round_period, k_bounds, delta_bounds,
                                mode="fixed_k", fixed_k=fixed_k)
        for p in profiles:
            specs.append(DeviceSpec(p, ctl.register(p),
                                    compressor_override or "topk",
                                    error_feedback))
    elif method == "opt_lf":   # fixed δ, optimize k (Tab. 2)
        ctl = FedLuckController(round_period, k_bounds, delta_bounds,
                                mode="fixed_delta", fixed_delta=fixed_delta)
        for p in profiles:
            specs.append(DeviceSpec(p, ctl.register(p),
                                    compressor_override or "topk",
                                    error_feedback))
    elif method in ("fedper", "fedavg_topk"):
        for p in profiles:
            plan = Plan(fixed_k, fixed_delta, 0.0,
                        fixed_k * p.alpha + fixed_delta * p.beta, 0)
            specs.append(DeviceSpec(p, plan, compressor_override or "topk",
                                    error_feedback))
    elif method in ("fedbuff", "fedasync"):   # no compression baselines
        for p in profiles:
            plan = Plan(fixed_k, 1.0, 0.0, fixed_k * p.alpha + p.beta, 0)
            specs.append(DeviceSpec(p, plan, compressor_override or "none",
                                    error_feedback))
    else:
        raise ValueError(f"unknown method {method}")
    return specs


STRATEGY_FOR_METHOD = {
    "fedluck": "periodic", "fedper": "periodic", "opt_cr": "periodic",
    "opt_lf": "periodic", "fedbuff": "fedbuff", "fedasync": "fedasync",
    "fedavg_topk": "sync",
}
