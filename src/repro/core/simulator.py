"""Event-driven AFL simulator with a simulated wall clock (paper Sec 4.3).

Real JAX training, simulated time: each device runs its k_i local
momentum-SGD steps as one jitted `lax.scan`, compresses the pseudo-gradient
(Eq. 4) with its δ_i, and "uploads" — the upload lands on the simulated
clock at  t + k_i·α_i + rate_i·β_i  (Eq. 5). The server strategy decides
when aggregation happens (periodic / buffered / async / sync) and the
simulator hands fresh global models back to devices.

Two execution engines share the same event semantics:

  engine="batched" (default) — the device-resident hot path. Pending device
  cycles that cannot be affected by any intervening aggregation event are
  drained from the event heap together, grouped into plan-time buckets
  (same local-k / compressor family / error-feedback), split into exact
  power-of-two chunks, and dispatched through one `jax.vmap`-ed
  local-round + compress function per chunk (dispatch-then-collect, so
  host-side stacking overlaps asynchronous XLA compute). EF residuals live in a single stacked
  [num_devices, d] device array updated with `.at[rows]` scatters
  (`donate_argnums` on the residual stack lets XLA scatter in place; the
  flat model is a fresh per-dispatch upload with no aliasable output, so
  donating it would be a no-op), and sparse compressors ship arrivals as compact
  (values, indices) pairs instead of dense d-length vectors. Per-device
  batches come from `data.pipeline.StackedLoader`s; `prefetch=0` (the
  default) stacks synchronously — background prefetch threads only pay off
  when spare cores exist, so raise `prefetch` on multi-core hosts. Within a
  bucket, mixed δ_i are handled by `compression.topk_capped` (traced
  per-row k under a static cap), so results stay *bitwise identical* to the
  sequential engine (tested in test_simulator_batched.py).

  engine="sequential" — the pre-batching reference path: one Python cycle,
  one jit dispatch, and one dense host pull per arrival, with EF residuals
  in a host-side per-device dict. Kept as the equivalence/benchmark
  baseline (`benchmarks/sim_bench.py` measures batched speedup against it).

Communication accounting charges the actual payload shape by default
(`wire_accounting="payload"`): strict value/index bits plus the kept-count
header compact (values, indices) payloads carry — the same wire format the
pod-sync compact path ships (dist.collectives). Upload *time* still follows
the paper model (time = rate·β, Eq. 5). `wire_accounting="strict"` drops
the header (the pre-header layout, also reachable via the legacy
`count_index_bits=True`); `wire_accounting="analytic"` restores the paper's
rate·d·32 estimate.

Resilience (repro.ft) is first-class in BOTH engines — failure-injected
runs no longer fall back to the sequential path. A `FailureSchedule`
injects device crashes: an upload in flight when an outage begins is lost
and the device restarts at recovery with the then-current model. A
`LossyChannel` models per-device upload loss with timeout/backoff
retransmission (each attempt charged full simulated upload time and wire
bits, so Eq. 5 stays honest under retries), time-varying bandwidth
(`BandwidthDrift`), and NaN-corrupting links; `StragglerDrift` slows a
device's α mid-run. The batched drain treats all of these as scheduling
constraints: cycle outcomes (arrival / loss / retry schedule) are computed
host-side at heap-pop time — they depend only on per-device RNG streams,
never on the payload — so lost cycles still run their compute (EF
residual semantics match the sequential engine), retry and recovery
starts re-enter the heap mid-drain in exact event order, and the drain
horizon uses true arrival times including retransmission delays. Batched
and sequential engines stay bitwise identical on failure-injected,
lossy-channel, drifting fleets (tests/test_simulator_batched.py).
Server-side, an `UpdateSanitizer` (core.aggregation) guards aggregation
against NaN/Inf payloads, norm outliers, and zombie updates past a
staleness cap; a `FedLuckController` passed to the simulator turns
observed α/β drift into mid-run re-plans. Per-category drop/retry/replan
counters surface in `History.counters` and `Record.drops`;
`benchmarks/chaos_bench.py` sweeps loss × crash × drift for FedLuck vs.
the baselines.
"""
from __future__ import annotations

import contextlib
import dataclasses
import heapq
import math
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression as C
from repro.core.aggregation import (Arrival, GlobalModel, PeriodicAggregator,
                                    SanitizerConfig, SparseUpdate,
                                    SyncAggregator, UpdateSanitizer,
                                    make_aggregator)
from repro.core import factor
from repro.core.controller import DeviceProfile, FedLuckController
from repro.core.factor import Plan
from repro.obs import profiling as _prof
from repro.obs.metrics import STALENESS_BUCKETS
from repro.obs.profiling import PhaseTimers
from repro.obs.trace import CONTROLLER_TRACK, SERVER_TRACK, device_track

# shared no-op phase context for the uninstrumented (timers=None) path
_NULL_PHASE = contextlib.nullcontext()

# fixed metric bucket grids (no Date/random in hot paths — pure constants)
_SIZE_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256)
_DENSITY_BUCKETS = (0.01, 0.02, 0.05, 0.1, 0.2, 0.5, 1.0)


# ----------------------------------------------------------------------- task
@dataclasses.dataclass
class TrainTask:
    """A trainable model + data, in pure-function form."""
    name: str
    init_fn: Callable[[jax.Array], Any]              # rng -> params pytree
    loss_fn: Callable[[Any, dict], jax.Array]        # (params, batch) -> scalar
    acc_fn: Callable[[Any, dict], jax.Array]         # (params, batch) -> scalar
    dataset: Any                                     # train split (repro.data)
    test_batch: dict                                 # held-out eval batch
    batch_size: int = 64


@dataclasses.dataclass
class DeviceSpec:
    """Static per-device simulation knobs."""
    profile: DeviceProfile
    plan: Plan
    compressor: str = "topk"      # topk | randk | qsgd | signsgd | none
    error_feedback: bool = False
    compressor_kwargs: dict = dataclasses.field(default_factory=dict)

    @property
    def rate(self) -> float:
        """Effective wire rate (fraction of a full fp32 gradient)."""
        if self.compressor in ("topk", "topk_threshold", "randk"):
            return self.plan.delta
        if self.compressor == "qsgd":
            # (log2(levels) + sign) bits per coordinate over fp32
            levels = int(self.compressor_kwargs.get("levels", 256))
            return (math.log2(levels) + 1.0) / 32.0
        if self.compressor == "signsgd":
            return 1.0 / 32.0
        return 1.0

    def _ckw_key(self) -> tuple:
        return tuple(sorted(self.compressor_kwargs.items()))


@dataclasses.dataclass
class Record:
    time: float
    round: int
    accuracy: float
    loss: float
    gbits: float
    mean_staleness: float
    drops: int = 0      # cumulative lost/dropped/sanitized updates so far
    # per-eval-window fault deltas: {counter: change since the previous
    # eval}, zero entries omitted — makes drops/retries/re-plans
    # attributable to a window (`drops` above stays cumulative for
    # back-compat). With metrics attached, also carries the window's
    # staleness bucket counts under "staleness_counts".
    window: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class History:
    records: list[Record] = dataclasses.field(default_factory=list)
    # final fault/resilience counters (crash losses, channel retries/drops,
    # sanitizer rejections, controller re-plans) — see
    # AFLSimulator.fault_counters
    counters: dict = dataclasses.field(default_factory=dict)

    def time_to_accuracy(self, target: float) -> float | None:
        for r in self.records:
            if r.accuracy >= target:
                return r.time
        return None

    def bits_to_accuracy(self, target: float) -> float | None:
        for r in self.records:
            if r.accuracy >= target:
                return r.gbits
        return None

    def final_accuracy(self, window: int = 3) -> float:
        if not self.records:
            return 0.0
        return float(np.mean([r.accuracy for r in self.records[-window:]]))


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


# Largest vmap chunk a bucket dispatches at once. Chunks are exact binary
# decompositions of the bucket occupancy (10 -> 8+2), so no lane is ever a
# padded duplicate, and each bucket compiles at most log2(cap)+1 shape
# variants over a whole run.
_CHUNK_CAP = 16


def _chunk_sizes(n: int, cap: int = _CHUNK_CAP) -> list[int]:
    out, size = [], cap
    while n:
        while size > n:
            size >>= 1
        reps, n = divmod(n, size)
        out.extend([size] * reps)
    return out


# Compressors whose payload carries explicit indices → compact wire pull.
# Shared with the wire-bit accounting (compression.sparse_wire) so the
# charged shape and the shipped shape agree.
_SPARSE_WIRE = C.SPARSE_WIRE


# ------------------------------------------------------------------ simulator
class AFLSimulator:
    def __init__(self, task: TrainTask, devices: list[DeviceSpec],
                 strategy: str = "periodic", *, round_period: float = 1.0,
                 eta_l: float = 0.05, eta_g: float = 1.0,
                 momentum: float = 0.9, seed: int = 0,
                 client_indices: list[np.ndarray] | None = None,
                 failure_schedule=None, channel=None, stragglers=None,
                 controller: FedLuckController | None = None,
                 sanitizer=None, count_index_bits: bool = False,
                 wire_accounting: str = "payload",
                 strategy_kwargs: dict | None = None,
                 engine: str = "batched", prefetch: int = 0,
                 tracer=None, metrics=None, timers=None):
        if engine not in ("batched", "sequential"):
            raise ValueError(f"unknown engine {engine}")
        if wire_accounting not in ("payload", "strict", "analytic"):
            raise ValueError(f"unknown wire_accounting {wire_accounting!r}")
        self.task = task
        self.devices = {d.profile.device_id: d for d in devices}
        self.round_period = float(round_period)
        self.eta_l, self.eta_g, self.momentum = eta_l, eta_g, momentum
        # ---- fault models (all optional, both engines):
        # failure_schedule: repro.ft.FailureSchedule crash windows
        # channel: repro.ft.LossyChannel (loss/retry/drift/corruption);
        #     stateful — give each simulator its own instance
        # stragglers: list[repro.ft.StragglerDrift] α slowdowns
        # controller: FedLuckController fed observed α/β each cycle for
        #     drift-triggered mid-run re-plans (pass the instance that
        #     planned the fleet, or the first observation re-solves)
        self.failure_schedule = failure_schedule
        self.channel = channel
        self._stragglers = list(stragglers or [])
        self.controller = controller
        self._crash_lost = 0
        # ---- observability (repro.obs), all optional and host-side only:
        # tracer: obs.Tracer recording spans/instants in SIMULATED time —
        #     emission happens only at engine-shared seams, so batched and
        #     sequential runs produce identical event lists
        # metrics: obs.MetricsRegistry (counters/gauges/fixed-bucket
        #     histograms; engine-specific internals live under engine.*)
        # timers: obs.PhaseTimers perf_counter wall-clock phase totals
        #     (defaults on whenever metrics are attached)
        # The default (all None) path pays one `is not None` predicate per
        # site and stays bitwise identical: instrumentation reads state but
        # never consumes RNG or touches the event heap.
        self._tracer = tracer
        self._metrics = metrics
        self._timers = timers if timers is not None else (
            PhaseTimers() if metrics is not None else None)
        self._last_counters: dict = {}
        if tracer is not None and channel is not None:
            channel.trace_attempts = True
        # prefetch composes with mid-run re-plans: StackedLoader's queue
        # holds individual per-step batches (k-agnostic), so a re-plan's
        # set_k only changes how many are popped per round — no stale
        # stacked rounds to flush (tested bitwise in test_simulator_batched)
        self.count_index_bits = count_index_bits
        self._wire_mode = "strict" if count_index_bits else wire_accounting
        self.strategy_name = strategy
        self.rng = np.random.RandomState(seed)
        self.engine = engine
        self._batched = engine == "batched"
        self.events_processed = 0

        # ---- params / flat spec
        params = task.init_fn(jax.random.PRNGKey(seed))
        flat, self.spec = C.flatten_pytree(params)
        self.dim = int(flat.shape[0])
        self.model = GlobalModel(np.asarray(flat), eta_g=eta_g)
        skw = dict(strategy_kwargs or {})
        if strategy in ("sync", "fedavg", "fedavg_topk"):
            skw.setdefault("num_devices", len(devices))
        self.agg = make_aggregator(strategy, self.model, **skw)
        if sanitizer is not None:
            if isinstance(sanitizer, SanitizerConfig):
                sanitizer = UpdateSanitizer(sanitizer)
            self.agg.sanitizer = sanitizer

        # ---- per-client data
        from repro.data.pipeline import DataLoader, StackedLoader
        n = len(task.dataset)
        if client_indices is None:
            from repro.data.partition import iid_partition
            client_indices = iid_partition(n, len(devices), seed=seed)
        self.loaders = {
            did: DataLoader(task.dataset, idx, batch_size=task.batch_size,
                            seed=seed + 17 * did)
            for did, idx in zip(sorted(self.devices), client_indices)}

        # ---- device-id <-> residual-stack row mapping (row N is a spare
        # scratch row, kept so the stack shape is stable if a future
        # dispatch policy ever needs a sink lane)
        self._dids = sorted(self.devices)
        self._rowof = {did: i for i, did in enumerate(self._dids)}
        self._scratch_row = len(self._dids)
        self._has_ef = any(s.error_feedback for s in devices)

        # ---- residual storage: stacked device array (batched) or host dict
        # (sequential, the pre-change layout)
        self._res_stack: jax.Array | None = None
        self._residuals: dict[int, np.ndarray] = {}
        if self._batched:
            if self._has_ef:
                self._res_stack = jnp.zeros(
                    (len(self._dids) + 1, self.dim), jnp.float32)
            self._stacked = {
                did: StackedLoader(self.loaders[did],
                                   self.devices[did].plan.k, prefetch)
                for did in self._dids}
            self._plan_buckets()
        else:
            self._residuals = {did: np.zeros((self.dim,), np.float32)
                               for did in self._dids}
            self._stacked = {}

        # ---- jitted compute caches
        self._seq_round = jax.jit(self._round_body())
        self._compress_fns: dict[tuple, Callable] = {}
        self._bucket_fns: dict[tuple, Callable] = {}
        self._eval_fn = jax.jit(self._make_eval())
        self._stal_ptr = 0   # staleness_log watermark for per-eval windows

    # --------------------------------------------------------------- teardown
    def close(self) -> None:
        """Stop prefetch threads (safe to call more than once)."""
        for sl in self._stacked.values():
            sl.close()

    # ---------------------------------------------------------- observability
    def _phase(self, name: str):
        """Wall-clock phase context (obs.PhaseTimers) or a shared no-op."""
        tm = self._timers
        return tm.phase(name) if tm is not None else _NULL_PHASE

    def _trace_down(self, did: int, t: float, recovery: float) -> None:
        """Device found down at cycle start: its outage window as a span."""
        tr = self._tracer
        if tr is not None:
            tr.span(device_track(did), "down", t, recovery)
        if self._metrics is not None:
            self._metrics.counter("sim.down_starts").inc()

    def _trace_agg_events(self, events) -> None:
        tr, m = self._tracer, self._metrics
        for ev in events:
            if tr is not None:
                tr.instant(SERVER_TRACK, "aggregate", ev.time,
                           round=ev.new_round, released=len(ev.release_to))
            if m is not None:
                m.counter("sim.aggregations").inc()

    def _trace_cycle(self, did: int, t: float, compute_end: float,
                     arrive, restart_at, attempts: int, corrupt: bool,
                     crashed: bool, give_up) -> None:
        """Spans/instants for one device cycle resolved by
        `_schedule_upload` — called at heap-pop time in BOTH engines, so
        event order matches the sequential pop order exactly."""
        tr = self._tracer
        spec = self.devices[did]
        track = device_track(did)
        tr.span(track, "local_round", t, compute_end,
                k=spec.plan.k, delta=spec.plan.delta)
        if self.channel is not None and self.channel.trace_attempts:
            for i, (s0, s1, lost) in enumerate(self.channel.last_attempts):
                tr.span(track, "upload_retry" if i else "upload", s0, s1,
                        attempt=i, lost=lost)
        elif arrive is not None:
            tr.span(track, "upload", compute_end, arrive)
        if crashed:
            end = arrive if arrive is not None else give_up
            tr.instant(track, "crash_lost", min(end, restart_at),
                       restart=restart_at)
        elif arrive is None:
            tr.instant(track, "channel_dropped", give_up, attempts=attempts)
        elif corrupt:
            tr.instant(track, "corrupted", arrive)

    # --------------------------------------------------------------- jit fns
    def _make_eval(self):
        loss_fn, acc_fn, spec = self.task.loss_fn, self.task.acc_fn, self.spec

        def ev(flat, batch):
            params = C.unflatten_pytree(flat, spec)
            return acc_fn(params, batch), loss_fn(params, batch)
        return ev

    def _round_body(self):
        """Pure fn: flat params + stacked batches[k] -> pseudo-gradient
        g = w0 - wk (Eq. 4). Shared verbatim by the sequential jit and the
        batched vmap so both engines are bitwise identical."""
        loss_fn, spec = self.task.loss_fn, self.spec
        eta_l, mom = self.eta_l, self.momentum

        def run(flat, batches):
            params = C.unflatten_pytree(flat, spec)
            mu0 = jax.tree.map(jnp.zeros_like, params)

            def step(carry, batch):
                p, mu = carry
                g = jax.grad(loss_fn)(p, batch)
                mu = jax.tree.map(lambda m, gg: mom * m + gg, mu, g)
                p = jax.tree.map(lambda pp, m: pp - eta_l * m, p, mu)
                return (p, mu), None

            (p1, _), _ = jax.lax.scan(step, (params, mu0), batches)
            f1, _ = C.flatten_pytree(p1)
            return flat - f1  # Eq. 4

        return run

    def _compressor_fn(self, spec_d: DeviceSpec):
        key = (spec_d.compressor, float(spec_d.plan.delta),
               spec_d.error_feedback, spec_d._ckw_key())
        if key in self._compress_fns:
            return self._compress_fns[key]
        if self._metrics is not None:
            self._metrics.counter("engine.compressor_compiles").inc()
        comp = C.make_compressor(spec_d.compressor, spec_d.plan.delta,
                                 **spec_d.compressor_kwargs)

        @jax.jit
        def run(g, residual, rngkey):
            cc, new_res = C.ef_compress(comp, g, residual, rngkey)
            return cc.dense(), new_res, cc.wire_bits

        @jax.jit
        def run_noef(g, rngkey):
            cc = comp(g, rngkey)
            return cc.dense(), cc.wire_bits

        fn = run if spec_d.error_feedback else run_noef
        self._compress_fns[key] = fn
        return fn

    # -------------------------------------------------- batched bucket engine
    def _bucket_key(self, s: DeviceSpec) -> tuple:
        """Plan-time bucket id. `topk` buckets by local-k and a power-of-two
        band over k_i = δ_i·d (mixed δ_i within a band ride in one vmap via
        a traced per-row k, wasting at most 2× selection work); δ_i = 1
        devices get a dedicated "full" band whose payload is the identity —
        no top-k sort at all, unlike the sequential path which full-sorts d
        elements per full-rate cycle. Other compressors need a static shape
        per δ, so δ joins the key."""
        if s.compressor == "topk":
            keep = C.num_keep(self.dim, s.plan.delta)
            band = "full" if keep >= self.dim else _next_pow2(keep)
            return (s.plan.k, "topk", band, s.error_feedback, s._ckw_key())
        return (s.plan.k, s.compressor, float(s.plan.delta),
                s.error_feedback, s._ckw_key())

    def _plan_buckets(self) -> None:
        members: dict[tuple, list[int]] = {}
        for did in self._dids:
            members.setdefault(self._bucket_key(self.devices[did]),
                               []).append(did)
        self._bucket_kcap = {}
        for bkey, dids in members.items():
            if bkey[1] == "topk" and bkey[2] != "full":
                self._bucket_kcap[bkey] = max(
                    C.num_keep(self.dim, self.devices[d].plan.delta)
                    for d in dids)

    @staticmethod
    def _bucket_sparse(bkey: tuple) -> bool:
        """True when the bucket's payload is a (values, indices) pair.
        The full-rate topk band ships dense: its payload IS the
        pseudo-gradient, and an index vector would be a d-length iota."""
        return bkey[1] in _SPARSE_WIRE and bkey[2] != "full"

    def _bucket_fn(self, bkey: tuple, P: int):
        """One jitted dispatch for a chunk of P same-bucket cycles. The
        bucket's k-cap joins the cache key: a mid-run re-plan can change
        which δ_i share a band, and a fn compiled for the old (smaller)
        cap would silently truncate the new bucket's top-k selection."""
        cache_key = (bkey, P, self._bucket_kcap.get(bkey))
        if cache_key in self._bucket_fns:
            return self._bucket_fns[cache_key]
        if self._metrics is not None:   # a new (bucket, chunk-shape) compile
            self._metrics.counter("engine.bucket_compiles").inc()
        _, name, delta, ef, ckw = bkey
        dim = self.dim
        local = self._round_body()
        sparse = self._bucket_sparse(bkey)

        if name == "topk" and delta == "full":
            # δ_i = 1 devices: top-d of d is the identity permutation, so
            # skip the O(d log d) sort the sequential path pays and ship the
            # accumulator itself. Reconstruction is exact (scatter-add of
            # every coordinate onto zeros == the vector, up to ±0.0 signs,
            # which no downstream arithmetic can distinguish).
            def compress(acc, key, krow):
                bits = jnp.asarray(krow, jnp.float32) * 64.0
                return acc, acc, bits
        elif name == "topk":
            kcap = self._bucket_kcap[bkey]

            def compress(acc, key, krow):
                cc = C.topk_capped(acc, krow, k_cap=kcap)
                return (cc.values, cc.indices), cc.dense(), cc.wire_bits
        elif name == "topk_threshold":
            comp = C.make_compressor(name, delta, **dict(ckw))
            kcap = C.num_keep(dim, delta)

            def compress(acc, key, krow):
                from repro.kernels import ops
                cc = comp(acc, key)
                dense = cc.dense()
                vals, idx = ops.compact_topk(dense, kcap)
                return (vals, idx), dense, cc.wire_bits
        else:
            comp = C.make_compressor(name, delta if delta is not None else 1.0,
                                     **dict(ckw))

            def compress(acc, key, krow):
                cc = comp(acc, key)
                dense = cc.dense()
                payload = (cc.values, cc.indices) if sparse else dense
                return payload, dense, cc.wire_bits

        if ef:
            def row(flat, res_row, batch, seed, krow):
                g = local(flat, batch)
                key = jax.random.PRNGKey(seed)
                acc = g + res_row                   # ef_compress, inlined so
                payload, dense, bits = compress(acc, key, krow)
                return payload, acc - dense, bits   # residual stays on device

            # Donate the [N+1, d] residual stack: it aliases the returned
            # updated stack, so XLA scatters the B fresh rows in place
            # instead of copying the whole fleet buffer per dispatch. The
            # flat model is NOT donated — no output aliases its shape (the
            # global model only changes server-side), so donation would be
            # a dead no-op that XLA warns about. Batches are [k, P, ...]
            # (vmap in_axes=1): the scan then slices contiguous [P, ...]
            # per-step blocks, which benches faster than a [P, k, ...]
            # layout whose scan slices are strided.
            @partial(jax.jit, donate_argnums=(1,))
            def bucket(flat, res_stack, rows, batches, seeds, krows):
                res_rows = res_stack[rows]
                payload, new_rows, bits = jax.vmap(
                    row, in_axes=(None, 0, 1, 0, 0))(
                        flat, res_rows, batches, seeds, krows)
                return payload, res_stack.at[rows].set(new_rows), bits
        else:
            def row(flat, batch, seed, krow):
                g = local(flat, batch)
                key = jax.random.PRNGKey(seed)
                payload, _, bits = compress(g, key, krow)
                return payload, bits

            @jax.jit
            def bucket(flat, batches, seeds, krows):
                return jax.vmap(row, in_axes=(None, 1, 0, 0))(
                    flat, batches, seeds, krows)

        self._bucket_fns[cache_key] = bucket
        return bucket

    def _alpha_mult(self, did: int, t: float) -> float:
        """Straggler-drift α multiplier active for a device at time t."""
        m = 1.0
        for s in self._stragglers:
            if s.device_id == did and s.start <= t:
                m *= s.alpha_multiplier
        return m

    def _cycle_span(self, did: int, t: float | None = None) -> float:
        spec = self.devices[did]
        a = spec.profile.alpha
        if t is not None:
            m = self._alpha_mult(did, t)
            if m != 1.0:
                a = a * m
        return spec.plan.k * a + spec.rate * spec.profile.beta

    # ----------------------------------------------------- fault-model helpers
    def _maybe_replan(self, did: int, t: float) -> None:
        """Feed observed α/β into the controller; apply a drift-triggered
        re-plan to the device (new k/δ; batched loader + buckets rebuilt).
        Called at cycle start in both engines, so the event timelines stay
        engine-identical."""
        if self.controller is None:
            return
        spec = self.devices[did]
        beta_m = (self.channel.beta_multiplier(did, t)
                  if self.channel is not None else 1.0)
        obs = DeviceProfile(did, spec.profile.alpha * self._alpha_mult(did, t),
                            spec.profile.beta * beta_m,
                            spec.profile.bandwidth_bps)
        plan = self.controller.update_profile(obs)
        if plan.k == spec.plan.k and plan.delta == spec.plan.delta:
            return
        if self._tracer is not None:
            self._tracer.instant(CONTROLLER_TRACK, "replan", t, device=did,
                                 k_old=spec.plan.k, k_new=plan.k,
                                 delta_old=spec.plan.delta,
                                 delta_new=plan.delta)
        if self._metrics is not None:
            self._metrics.counter("sim.replans").inc()
        spec.plan = plan
        if self._batched:
            # the stacked loader's queue holds per-step batches, so the new
            # k applies from the next round with no prefetched data wasted
            self._stacked[did].set_k(plan.k)
            self._plan_buckets()

    def _schedule_upload(self, did: int, t: float
                         ) -> tuple[float | None, float | None, int, bool,
                                    bool | None]:
        """Host-side outcome of the cycle a device starts at time t:
        `(arrive_time, restart_at, attempts, corrupt, ch_delivered)`.
        `arrive_time` is None when the upload never lands (crash mid-flight
        or channel gave up after max retries) — then `restart_at` says when
        the device begins a fresh cycle. `ch_delivered` is the channel-level
        outcome (None without a channel) — the payload-bit charge for
        retransmitted/dropped attempts (`LossyChannel.charge_wire`) keys off
        it once the payload size is known. Consumes only the channel's
        per-device RNG stream, so it is computable at heap-pop time before
        any compute is dispatched."""
        spec = self.devices[did]
        corrupt = False
        ch_delivered = None
        compute_end = t + spec.plan.k * spec.profile.alpha \
            * self._alpha_mult(did, t)
        if self.channel is not None:
            corrupt = self.channel.maybe_corrupt(did)
            arrive, attempts, give_up = self.channel.transmit(
                did, compute_end, spec.rate * spec.profile.beta)
            ch_delivered = arrive is not None
        else:
            arrive, attempts, give_up = t + self._cycle_span(did, t), 1, None
        in_flight_end = arrive if arrive is not None else give_up
        crashed, restart_at = False, None
        if self.failure_schedule is not None:
            rec = self.failure_schedule.crash_recovery(did, t, in_flight_end)
            if rec is not None:   # an outage opened mid-flight: upload lost
                self._crash_lost += 1
                crashed, restart_at = True, max(rec, t + 1e-9)
        if not crashed and arrive is None:
            restart_at = give_up
        m = self._metrics
        if m is not None:
            m.counter("sim.cycles").inc()
            m.counter("sim.upload_attempts").inc(attempts)
            m.histogram("sim.local_k", _SIZE_BUCKETS).observe(spec.plan.k)
            m.histogram("sim.compression_density",
                        _DENSITY_BUCKETS).observe(spec.plan.delta)
        if self._tracer is not None:
            self._trace_cycle(did, t, compute_end, arrive, restart_at,
                              attempts, corrupt, crashed, give_up)
        if crashed or arrive is None:
            return None, restart_at, attempts, corrupt, ch_delivered
        return arrive, None, attempts, corrupt, ch_delivered

    @staticmethod
    def _poison(update):
        """Corrupted-in-transit payload: every shipped value becomes NaN.
        Only an aggregation-side sanitizer keeps this out of the model."""
        if isinstance(update, SparseUpdate):
            return SparseUpdate(np.full_like(update.values, np.nan),
                                update.indices, update.dim, update.kept)
        return np.full_like(np.asarray(update), np.nan)

    def fault_counters(self) -> dict:
        """Resilience telemetry: crash losses, channel attempt/retry/drop/
        corruption counts, sanitizer rejections, controller re-plans, plus
        the cross-category `drops_total` that `Record.drops` snapshots."""
        c = {"crash_lost": self._crash_lost}
        if self.channel is not None:
            c.update(self.channel.counters)
        san = getattr(self.agg, "sanitizer", None)
        if san is not None:
            c.update(san.counts)
        if self.controller is not None:
            c["replans"] = self.controller.replans
        c["drops_total"] = int(c["crash_lost"] + c.get("channel_dropped", 0)
                               + c.get("sanitized_dropped", 0))
        return c

    def _process_starts_batched(self, starts: list, push) -> None:
        """Run a drained batch of device cycles through bucketed vmap
        dispatches. `starts` is [(t, did, model_round, arrive, attempts,
        corrupt, ch_delivered)] in heap-pop order, with the upload outcome already
        resolved at drain time (`_schedule_upload`); arrivals are pushed
        back in that same order so heap tie-breaking (and the host RNG
        stream) match the sequential engine exactly. Lost cycles (crash or
        channel give-up: arrive is None) are still dispatched — their
        compute advances the loader, RNG, and EF residual exactly like the
        sequential engine — but land no arrival (their restart event was
        pushed during the drain).

        Two phases: dispatch every chunk of every bucket first (jitted CPU
        computations run asynchronously on XLA worker threads, so host-side
        stacking of the next chunk overlaps device compute of the previous
        one), then pull the payloads."""
        order = []
        for t, did, mr, arrive, attempts, corrupt, ch_del in starts:
            stacked = self._stacked[did].next()
            seed = self.rng.randint(0, 2 ** 31 - 1)
            order.append((t, did, mr, stacked, seed))

        buckets: dict[tuple, list] = {}
        for item in order:
            buckets.setdefault(self._bucket_key(self.devices[item[1]]),
                               []).append(item)
        if self._metrics is not None:
            m = self._metrics
            m.histogram("engine.drain_size", _SIZE_BUCKETS).observe(
                len(starts))
            m.gauge("engine.buckets").set(len(buckets))
            occ = m.histogram("engine.bucket_occupancy", _SIZE_BUCKETS)
            for items in buckets.values():
                occ.observe(len(items))
        # one host->device model upload per drain: the drain invariant is
        # precisely that no aggregation lands inside it, so every chunk
        # reads the same global model
        flat = jnp.asarray(self.model.w)
        pending = []
        chunk_hist = (self._metrics.histogram("engine.chunk_size",
                                              _SIZE_BUCKETS)
                      if self._metrics is not None else None)
        with self._phase("dispatch"):
            for bkey, items in buckets.items():
                pos = 0
                for size in _chunk_sizes(len(items)):
                    if chunk_hist is not None:
                        chunk_hist.observe(size)
                    pending.append(self._dispatch_chunk(
                        bkey, items[pos:pos + size], flat))
                    pos += size
        results: dict[int, tuple] = {}
        with self._phase("collect"):
            for rec in pending:
                self._collect_chunk(rec, results)

        for t, did, mr, arrive, attempts, corrupt, ch_del in starts:
            update, bits = results[did]
            if self.channel is not None and ch_del is not None:
                self.channel.charge_wire(bits, attempts, ch_del)
            if arrive is None:
                continue   # upload lost; compute ran, restart already queued
            if corrupt:
                update = self._poison(update)
            push(arrive, "arrival", Arrival(did, update, mr, bits * attempts,
                                            arrive))

    def _dispatch_chunk(self, bkey: tuple, items: list, flat):
        """Launch one vmapped dispatch for an exact power-of-two chunk of
        same-bucket cycles; returns the in-flight record for collection."""
        B = len(items)
        if B == 1:
            # zero-copy: a [k, 1, ...] view of the loader stack
            batches = {key: items[0][3][key][:, None] for key in items[0][3]}
        else:
            batches = {key: np.stack([it[3][key] for it in items], axis=1)
                       for key in items[0][3]}
        seeds = np.asarray([it[4] for it in items], np.uint32)
        krows = np.asarray(
            [C.num_keep(self.dim, self.devices[it[1]].plan.delta)
             for it in items], np.int32)
        fn = self._bucket_fn(bkey, B)
        with _prof.annotate("sim.bucket_dispatch"):
            if bkey[3]:   # error feedback
                rows = np.asarray([self._rowof[it[1]] for it in items],
                                  np.int32)
                payload, self._res_stack, bits = fn(
                    flat, self._res_stack, rows, batches, seeds, krows)
            else:
                payload, bits = fn(flat, batches, seeds, krows)
        return bkey, items, payload, bits

    def _collect_chunk(self, rec, results: dict) -> None:
        bkey, items, payload, bits = rec
        payload, bits_host = jax.device_get((payload, bits))
        if self._bucket_sparse(bkey):
            vals, idxs = payload
            for i, it in enumerate(items):
                did = it[1]
                # kept-count header of the compact wire format; exact-k
                # compressors know it statically, threshold selection only
                # on device (header still charged via _wire_bits)
                kept = (C.num_keep(self.dim, self.devices[did].plan.delta)
                        if bkey[1] in ("topk", "randk") else None)
                results[did] = (SparseUpdate(vals[i], idxs[i], self.dim,
                                             kept),
                                self._wire_bits(did, bits_host[i]))
        else:
            dense = payload
            for i, it in enumerate(items):
                did = it[1]
                results[did] = (dense[i], self._wire_bits(did, bits_host[i]))

    def _wire_bits(self, did: int, strict_bits) -> float:
        """Bits charged for one upload. "payload" (default) charges the
        compact wire shape — strict value/index bits plus the kept-count
        header when the payload ships sparse (the static rule is identical
        in both engines, so they stay bitwise-equal); "strict" drops the
        header; "analytic" is the paper's rate·d·32 estimate."""
        spec = self.devices[did]
        if self._wire_mode == "analytic":
            bits = spec.rate * self.dim * 32.0
            if self._metrics is not None:
                self._metrics.counter("sim.wire_payload_bits").inc(bits)
            return bits
        bits = float(strict_bits)
        header = 0.0
        if self._wire_mode == "payload" and C.sparse_wire(
                spec.compressor, self.dim, spec.plan.delta):
            header = float(C.HEADER_BITS)
        if self._metrics is not None:
            self._metrics.counter("sim.wire_payload_bits").inc(bits)
            if header:
                self._metrics.counter("sim.wire_header_bits").inc(header)
        return bits + header

    # ----------------------------------------------------------- device cycle
    def _device_compute(self, did: int) -> tuple[np.ndarray, Any]:
        """Sequential engine: one local round + compression against the
        current global model. Always runs — even when the upload is already
        known to be lost — so the loader, host RNG, and EF residual advance
        exactly as in the batched engine."""
        spec = self.devices[did]
        k = spec.plan.k
        loader = self.loaders[did]
        batches = [loader.next() for _ in range(k)]
        stacked = {kk: np.stack([b[kk] for b in batches]) for kk in batches[0]}
        g = self._seq_round(jnp.asarray(self.model.w), stacked)

        rngkey = jax.random.PRNGKey(self.rng.randint(0, 2 ** 31 - 1))
        if spec.error_feedback:
            dense, new_res, strict_bits = self._compressor_fn(spec)(
                g, jnp.asarray(self._residuals[did]), rngkey)
            self._residuals[did] = np.asarray(new_res)
        else:
            dense, strict_bits = self._compressor_fn(spec)(g, rngkey)
        return np.asarray(dense), strict_bits

    # ------------------------------------------------------------- residual IO
    def residual_snapshot(self) -> tuple[np.ndarray, np.ndarray]:
        """(device_ids, stacked [N, d] residuals) — checkpoint payload."""
        ids = np.asarray(self._dids, np.int64)
        if self._batched:
            if self._res_stack is None:
                stack = np.zeros((len(self._dids), self.dim), np.float32)
            else:
                stack = np.asarray(self._res_stack[:len(self._dids)])
        else:
            stack = np.stack([self._residuals[d] for d in self._dids]) \
                if self._dids else np.zeros((0, self.dim), np.float32)
        return ids, stack

    def load_residuals(self, ids: np.ndarray, stacked: np.ndarray) -> None:
        """Restore per-device EF residuals from a checkpoint payload."""
        if self._batched:
            if self._res_stack is None:
                self._res_stack = jnp.zeros(
                    (len(self._dids) + 1, self.dim), jnp.float32)
            rows = np.asarray([self._rowof[int(d)] for d in ids], np.int32)
            self._res_stack = self._res_stack.at[rows].set(
                np.asarray(stacked, np.float32))
        else:
            for i, did in enumerate(np.asarray(ids).tolist()):
                self._residuals[int(did)] = \
                    np.asarray(stacked[i], np.float32)

    # -------------------------------------------------------------------- run
    def run(self, total_rounds: int = 50, eval_every: int = 1,
            max_sim_time: float = math.inf) -> History:
        hist = History()
        heap: list = []
        seq = 0

        def push(t, kind, payload):
            nonlocal seq
            heapq.heappush(heap, (t, seq, kind, payload))
            seq += 1

        periodic = isinstance(self.agg, PeriodicAggregator)
        syncb = isinstance(self.agg, SyncAggregator)
        if syncb:
            self.agg.begin_round(0.0, list(self.devices))

        # kick off every device at t=0 with the initial model
        for did in self.devices:
            push(0.0, "start", (did, self.model.round))
        if periodic:
            push(self.round_period, "boundary", 1)

        evals_done = 0
        last_t = 0.0
        while heap:
            t, _, kind, payload = heapq.heappop(heap)
            if t > max_sim_time or self.model.round >= total_rounds:
                break
            last_t = t
            self.events_processed += 1

            if kind == "start":
                if self._batched:
                    # Drain every start that must precede the earliest
                    # possible completion of the drained set: no aggregation
                    # (= model change) can land in between, so the whole
                    # group reads the same global model and batches safely.
                    # Each popped start resolves its upload outcome here, at
                    # pop time: down devices just queue their recovery,
                    # lost uploads (crash / channel give-up) queue their
                    # restart immediately — re-entering the heap so the
                    # drain sees them in exact sequential event order — and
                    # delivered uploads bound the horizon with their TRUE
                    # arrival time (retries included). A device may appear
                    # only once per drain — buffered strategies can release
                    # the same device several times at one timestamp, and
                    # those cycles chain through its EF residual, so they
                    # must run in separate drains.
                    starts, seen, horizon = [], set(), math.inf
                    while True:
                        did, mr = payload
                        if self.failure_schedule is not None and \
                                self.failure_schedule.is_down(did, t):
                            rec = self.failure_schedule.recovery_time(did, t)
                            self._trace_down(did, t, rec)
                            push(rec, "start", (did, self.model.round))
                        else:
                            self._maybe_replan(did, t)
                            arrive, restart_at, attempts, corrupt, ch_del = \
                                self._schedule_upload(did, t)
                            if arrive is None:
                                push(restart_at, "start",
                                     (did, self.model.round))
                            else:
                                horizon = min(horizon, arrive)
                            seen.add(did)
                            starts.append(
                                (t, did, mr, arrive, attempts, corrupt,
                                 ch_del))
                        if not (heap and heap[0][2] == "start"
                                and heap[0][0] <= min(horizon, max_sim_time)
                                and heap[0][3][0] not in seen):
                            break
                        t, _, _, payload = heapq.heappop(heap)
                        last_t = t
                        self.events_processed += 1
                    if starts:
                        with self._phase("heap_drain"):
                            self._process_starts_batched(starts, push)
                    continue
                did, mr = payload
                if self.failure_schedule is not None and \
                        self.failure_schedule.is_down(did, t):
                    rec = self.failure_schedule.recovery_time(did, t)
                    self._trace_down(did, t, rec)
                    push(rec, "start", (did, self.model.round))
                    continue
                self._maybe_replan(did, t)
                arrive, restart_at, attempts, corrupt, ch_del = \
                    self._schedule_upload(did, t)
                with self._phase("dispatch"):
                    update, strict_bits = self._device_compute(did)
                per_upload = self._wire_bits(did, strict_bits)
                if self.channel is not None and ch_del is not None:
                    self.channel.charge_wire(per_upload, attempts, ch_del)
                if arrive is None:  # crashed mid-flight / channel gave up
                    push(restart_at, "start", (did, self.model.round))
                else:
                    if corrupt:
                        update = self._poison(update)
                    push(arrive, "arrival",
                         Arrival(did, update, mr, per_upload * attempts,
                                 arrive))

            elif kind == "arrival":
                a: Arrival = payload
                tr = self._tracer
                if tr is not None:
                    tr.instant(SERVER_TRACK, "arrival", t,
                               device=a.device_id, round=a.model_round,
                               bits=a.wire_bits)
                if self._metrics is not None:
                    self._metrics.counter("sim.arrivals").inc()
                    self._metrics.counter("sim.wire_bits_arrived").inc(
                        a.wire_bits)
                san = (getattr(self.agg, "sanitizer", None)
                       if tr is not None else None)
                san_before = dict(san.counts) if san is not None else None
                with self._phase("aggregate"):
                    events = self.agg.on_arrival(t, a)
                if san_before is not None:
                    for cat, n in san.counts.items():
                        for _ in range(n - san_before[cat]):
                            tr.instant(SERVER_TRACK, cat, t,
                                       device=a.device_id)
                self._trace_agg_events(events)
                for ev in events:
                    for did in ev.release_to:
                        push(ev.time, "start", (did, self.model.round))
                    if syncb and ev.release_to:
                        self.agg.begin_round(ev.time, list(self.devices))
                if not events and not periodic and not syncb:
                    # buffered strategy: device waits; FedBuff hands the
                    # *current* model back immediately so training continues
                    push(t, "start", (a.device_id, self.model.round))
                if events and eval_every and \
                        self.model.round >= evals_done * eval_every:
                    self._eval(hist, t)
                    evals_done += 1

            elif kind == "boundary":
                r = payload
                with self._phase("aggregate"):
                    events = self.agg.on_round_boundary(t)
                self._trace_agg_events(events)
                for ev in events:
                    for did in ev.release_to:
                        push(ev.time, "start", (did, self.model.round))
                push(t + self.round_period, "boundary", r + 1)
                if eval_every and self.model.round >= evals_done * eval_every:
                    self._eval(hist, t)
                    evals_done += 1

        # closing record: the break-event time when we stopped early, else
        # the LAST PROCESSED event time — never max_sim_time, which is inf
        # by default and would poison History.time_to_accuracy.
        self._eval(hist, t if heap else last_t)
        hist.counters = self.fault_counters()
        if self._metrics is not None:
            # overwrite rather than re-derive: faults.* must equal
            # History.counters EXACTLY, whatever the engine interleaving
            self._metrics.merge_totals("faults.", hist.counters)
            self._metrics.gauge("sim.events_processed").set(
                self.events_processed)
            if self._timers is not None:
                self._timers.export_to(self._metrics)
        return hist

    def _eval(self, hist: History, t: float):
        with self._phase("eval"):
            acc, loss = self._eval_fn(jnp.asarray(self.model.w),
                                      self.task.test_batch)
            acc, loss = float(acc), float(loss)
        # mean staleness over arrivals aggregated since the LAST eval: a
        # fixed last-N slice would mix entries across aggregation rounds.
        window = self.agg.staleness_log[self._stal_ptr:]
        self._stal_ptr = len(self.agg.staleness_log)
        cnt = self.fault_counters()
        fault_window = {k: cnt[k] - self._last_counters.get(k, 0)
                        for k in cnt if cnt[k] != self._last_counters.get(k, 0)}
        self._last_counters = cnt
        if self._metrics is not None:
            h = self._metrics.histogram("sim.staleness", STALENESS_BUCKETS)
            before = list(h.counts)
            for s in window:
                h.observe(s)
            fault_window["staleness_counts"] = [
                a - b for a, b in zip(h.counts, before)]
        if self._tracer is not None:
            self._tracer.instant(SERVER_TRACK, "eval", t,
                                 round=int(self.model.round),
                                 accuracy=acc, loss=loss)
        hist.records.append(Record(
            time=float(t), round=int(self.model.round),
            accuracy=acc, loss=loss,
            gbits=self.agg.total_bits / 1e9,
            mean_staleness=float(np.mean(window)) if window else 0.0,
            drops=cnt["drops_total"], window=fault_window))


# ------------------------------------------------------------ device builders
def make_heterogeneous_devices(
        num: int, model_bits: float, *, base_alpha: float = 0.02,
        alpha_spread: float = 4.0, bw_range: tuple = (0.25e6, 2e6),
        seed: int = 0) -> list[DeviceProfile]:
    """Paper Sec 4.3: α ~ U[a, 4a]; bandwidth ~ U[0.25, 2] Mb/s."""
    rng = np.random.RandomState(seed)
    out = []
    for i in range(num):
        alpha = rng.uniform(base_alpha, base_alpha * alpha_spread)
        bw = rng.uniform(*bw_range)
        out.append(DeviceProfile.from_bandwidth(i, alpha, model_bits, bw))
    return out


def _snap_k(plan: Plan, p: DeviceProfile, round_period: float,
            k_grid, k_bounds, delta_bounds,
            fixed_delta: float | None = None) -> Plan:
    """Snap a solver-chosen k to the nearest grid value and re-optimize δ
    at the snapped k (or keep δ when it was fixed). Bounds the number of
    distinct local-round shapes a fleet compiles — the batched engine jits
    one vmapped cycle per (k, bucket) pair — at a tiny φ cost."""
    lo, hi = int(k_bounds[0]), int(k_bounds[1])
    cand = sorted({min(max(int(g), lo), hi) for g in k_grid})
    k = min(cand, key=lambda g: (abs(g - plan.k), g))
    if k == plan.k:
        return plan
    if fixed_delta is not None:
        rt = k * p.alpha + fixed_delta * p.beta
        return Plan(k, float(fixed_delta),
                    float(factor.phi(k, fixed_delta, p.alpha, p.beta,
                                     round_period)),
                    rt, int(math.ceil(rt / round_period)))
    return factor.solve_plan_fixed_k(p.alpha, p.beta, round_period, k,
                                     delta_bounds=delta_bounds)


def plan_devices(profiles: list[DeviceProfile], method: str,
                 round_period: float, *, k_bounds=(1, 60),
                 delta_bounds=(1e-3, 1.0), fixed_k: int = 10,
                 fixed_delta: float = 0.1,
                 compressor_override: str | None = None,
                 error_feedback: bool = False,
                 compressor_kwargs: dict | None = None,
                 k_grid: list[int] | None = None,
                 controller: FedLuckController | None = None
                 ) -> list[DeviceSpec]:
    """Build DeviceSpecs for one of the 5 methods of the paper's Sec 4.

    `k_grid` (optional, methods that optimize k): snap each plan's k to the
    nearest grid value and re-solve δ at that k — see `_snap_k`.
    `controller` (optional, fedluck only): plan through a caller-owned
    controller instead of a throwaway — pass the same instance to
    `AFLSimulator(controller=...)` so mid-run drift re-plans start from the
    profiles that planned the fleet.
    """
    method = method.lower()
    ckw = dict(compressor_kwargs or {})
    specs = []
    if method == "fedluck":
        ctl = controller or FedLuckController(round_period, k_bounds,
                                              delta_bounds)
        for p in profiles:
            plan = ctl.register(p)
            if k_grid:
                plan = _snap_k(plan, p, round_period, k_grid, k_bounds,
                               delta_bounds)
            specs.append(DeviceSpec(p, plan, compressor_override or "topk",
                                    error_feedback, ckw))
    elif method == "opt_cr":   # fixed k, optimize δ (Tab. 2)
        ctl = FedLuckController(round_period, k_bounds, delta_bounds,
                                mode="fixed_k", fixed_k=fixed_k)
        for p in profiles:
            specs.append(DeviceSpec(p, ctl.register(p),
                                    compressor_override or "topk",
                                    error_feedback, ckw))
    elif method == "opt_lf":   # fixed δ, optimize k (Tab. 2)
        ctl = FedLuckController(round_period, k_bounds, delta_bounds,
                                mode="fixed_delta", fixed_delta=fixed_delta)
        for p in profiles:
            plan = ctl.register(p)
            if k_grid:
                plan = _snap_k(plan, p, round_period, k_grid, k_bounds,
                               delta_bounds, fixed_delta=fixed_delta)
            specs.append(DeviceSpec(p, plan,
                                    compressor_override or "topk",
                                    error_feedback, ckw))
    elif method in ("fedper", "fedavg_topk"):
        for p in profiles:
            plan = Plan(fixed_k, fixed_delta, 0.0,
                        fixed_k * p.alpha + fixed_delta * p.beta, 0)
            specs.append(DeviceSpec(p, plan, compressor_override or "topk",
                                    error_feedback, ckw))
    elif method in ("fedbuff", "fedasync"):   # no compression baselines
        for p in profiles:
            plan = Plan(fixed_k, 1.0, 0.0, fixed_k * p.alpha + p.beta, 0)
            specs.append(DeviceSpec(p, plan, compressor_override or "none",
                                    error_feedback, ckw))
    else:
        raise ValueError(f"unknown method {method}")
    return specs


STRATEGY_FOR_METHOD = {
    "fedluck": "periodic", "fedper": "periodic", "opt_cr": "periodic",
    "opt_lf": "periodic", "fedbuff": "fedbuff", "fedasync": "fedasync",
    "fedavg_topk": "sync",
}
