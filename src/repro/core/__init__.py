"""FedLuck core: the paper's contribution as a composable library.

  compression  — C_δ operators (top-k et al.) + error feedback (Sec 2.2)
  factor       — key convergence factor φ(k, δ) and Eq. 15 solvers (Sec 3.2)
  controller   — α/β profiling + per-device (k_i, δ_i) planning (Alg. 1)
  aggregation  — periodic/buffered/async/sync servers (Sec 2.2, baselines)
  simulator    — event-driven AFL engine with simulated clock (Sec 4.3)
"""
from repro.core import aggregation, compression, controller, factor, simulator

__all__ = ["aggregation", "compression", "controller", "factor", "simulator"]
