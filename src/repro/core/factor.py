"""Key convergence factor φ(k, δ) and the joint solver (paper Eq. 14/15).

    φ(k, δ) = ((k·α + δ·β)² · (2 − δ) + T̃²) / (T̃² · k · √δ)

k ∈ [k_min, k_max] (integer local updating frequency), δ ∈ [δ_min, δ_max]
(top-k density). The paper solves this "heuristic optimization problem" per
device; we provide an exact-enough solver: dense log-grid over δ × integer
range over k, followed by golden-section refinement in δ for the best k.
The solver is numpy (runs on the controller host, tiny), with a jnp twin
for in-graph use.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


def phi(k, delta, alpha, beta, round_period):
    """Key convergence factor, vectorized over k/delta (numpy)."""
    k = np.asarray(k, dtype=np.float64)
    d = np.asarray(delta, dtype=np.float64)
    T = float(round_period)
    num = (k * alpha + d * beta) ** 2 * (2.0 - d) + T * T
    return num / (T * T * k * np.sqrt(d))


def staleness(k, delta, alpha, beta, round_period):
    """τ = ceil(d_i / T̃)  with  d_i = k·α + δ·β  (paper Sec 2.2)."""
    return np.ceil((np.asarray(k) * alpha + np.asarray(delta) * beta)
                   / float(round_period))


@dataclasses.dataclass(frozen=True)
class Plan:
    """Per-device decision (k_i, δ_i) + diagnostics."""
    k: int
    delta: float
    phi: float
    round_time: float     # d_i = kα + δβ seconds
    staleness: int        # ⌈d_i/T̃⌉


def solve_plan(alpha: float, beta: float, round_period: float,
               k_bounds: tuple[int, int] = (1, 200),
               delta_bounds: tuple[float, float] = (1e-4, 1.0),
               grid: int = 200) -> Plan:
    """Minimize φ over the box (Eq. 15). Exhaustive over k (integer),
    log-grid + golden-section over δ. Cost: O(k_range · grid) ~ 40k evals."""
    k_min, k_max = int(k_bounds[0]), int(k_bounds[1])
    d_min, d_max = float(delta_bounds[0]), float(delta_bounds[1])
    if not (0 < d_min <= d_max <= 1.0):
        raise ValueError(f"bad delta bounds {delta_bounds}")
    if not (1 <= k_min <= k_max):
        raise ValueError(f"bad k bounds {k_bounds}")

    ks = np.arange(k_min, k_max + 1)
    ds = np.geomspace(d_min, d_max, grid)
    K, D = np.meshgrid(ks, ds, indexing="ij")
    vals = phi(K, D, alpha, beta, round_period)
    i, j = np.unravel_index(np.argmin(vals), vals.shape)
    k_star = int(ks[i])

    # golden-section refine δ for k_star (φ is unimodal in δ on [d_min,d_max]
    # for fixed k in the regimes of interest; fall back to grid value if not)
    lo = ds[max(0, j - 1)]
    hi = ds[min(len(ds) - 1, j + 1)]
    gr = (math.sqrt(5) - 1) / 2
    a, b = lo, hi
    c, d_ = b - gr * (b - a), a + gr * (b - a)
    for _ in range(60):
        if phi(k_star, c, alpha, beta, round_period) < \
           phi(k_star, d_, alpha, beta, round_period):
            b = d_
        else:
            a = c
        c, d_ = b - gr * (b - a), a + gr * (b - a)
    d_star = float(np.clip(0.5 * (a + b), d_min, d_max))
    if phi(k_star, d_star, alpha, beta, round_period) > vals[i, j]:
        d_star = float(ds[j])

    p = float(phi(k_star, d_star, alpha, beta, round_period))
    rt = k_star * alpha + d_star * beta
    return Plan(k=k_star, delta=d_star, phi=p, round_time=rt,
                staleness=int(math.ceil(rt / round_period)))


def solve_plan_fixed_delta(alpha: float, beta: float, round_period: float,
                           delta: float,
                           k_bounds: tuple[int, int] = (1, 200)) -> Plan:
    """Baseline 'Opt. LF' (Tab. 2): δ fixed, optimize k only."""
    ks = np.arange(k_bounds[0], k_bounds[1] + 1)
    vals = phi(ks, delta, alpha, beta, round_period)
    i = int(np.argmin(vals))
    k = int(ks[i])
    rt = k * alpha + delta * beta
    return Plan(k, float(delta), float(vals[i]), rt,
                int(math.ceil(rt / round_period)))


def solve_plan_fixed_k(alpha: float, beta: float, round_period: float,
                       k: int,
                       delta_bounds: tuple[float, float] = (1e-4, 1.0),
                       grid: int = 400) -> Plan:
    """Baseline 'Opt. CR' (Tab. 2): k fixed, optimize δ only."""
    ds = np.geomspace(delta_bounds[0], delta_bounds[1], grid)
    vals = phi(k, ds, alpha, beta, round_period)
    j = int(np.argmin(vals))
    d = float(ds[j])
    rt = k * alpha + d * beta
    return Plan(int(k), d, float(vals[j]), rt,
                int(math.ceil(rt / round_period)))
