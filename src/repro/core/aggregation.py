"""Server-side aggregation strategies (the paper's 4 baselines + FedLuck).

All strategies speak one protocol driven by the event simulator:

    on_arrival(t_now, arrival)  -> list[AggregationEvent]
    on_round_boundary(t_now)    -> list[AggregationEvent]

`Arrival` carries the compressed pseudo-gradient (flat fp32), the round tag
of the model it was computed against, and wire bits. An AggregationEvent
says "the global model changed; these devices should be handed the new
model now". Strategies mutate `GlobalModel` in place.

  PeriodicAggregator  — FedPer & FedLuck (Eq. 6, fixed round period T̃)
  BufferedAggregator  — FedBuff (aggregate every K arrivals)
  AsyncAggregator     — FedAsync (apply immediately, staleness-weighted)
  SyncAggregator      — FedAvg(+TopK) (barrier over all devices)

Every strategy optionally runs arrivals through an `UpdateSanitizer`
before admitting them (attach one via `_Base.sanitizer`): non-finite
payloads are rejected outright, over-norm updates are clipped, and
zombie updates past a staleness cap τ_max are dropped or down-weighted.
Wire bits are charged *before* sanitization — a rejected upload still
spent its bandwidth. Rejected devices are still released (a dropped
update must not deadlock its sender), and per-category drop counters
accumulate on the sanitizer for `History` surfacing.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Union

import numpy as np


class SparseUpdate(NamedTuple):
    """Compact (values, indices) wire payload of a sparse pseudo-gradient —
    the same wire format the pod-sync compact path ships
    (dist.collectives): fixed-capacity value/index slots plus a kept-count
    header.

    The batched simulator engine pulls arrivals off-device in this form
    (k values + k int32 indices) instead of a dense d-length vector. Zero
    values are permitted (padding slots); indices must be unique so that
    scatter-add equals dense addition bitwise. `kept` is the header: the
    number of live (non-padding) slots, or None when the producer only
    knows it on device.
    """
    values: np.ndarray
    indices: np.ndarray
    dim: int
    kept: int | None = None

    def dense(self) -> np.ndarray:
        out = np.zeros((self.dim,), np.float32)
        np.add.at(out, self.indices, self.values)
        return out


Update = Union[np.ndarray, SparseUpdate]


def add_update(acc: np.ndarray, u: Update) -> None:
    """acc += u, scatter-adding sparse payloads (bitwise equal to the dense
    path: adding an explicit 0.0 never changes a float)."""
    if isinstance(u, SparseUpdate):
        np.add.at(acc, u.indices, u.values)
    else:
        acc += u


@dataclasses.dataclass
class Arrival:
    device_id: int
    update: Update           # dense (or compact sparse) compressed pseudo-grad
    model_round: int         # round tag the update was computed from
    wire_bits: float
    arrive_time: float


@dataclasses.dataclass
class AggregationEvent:
    time: float
    new_round: int
    release_to: list[int]    # device ids that receive the new global model
    staleness: dict[int, int]


class GlobalModel:
    """Flat fp32 global parameter vector + round counter."""

    def __init__(self, flat_params: np.ndarray, eta_g: float = 1.0):
        self.w = np.array(flat_params, dtype=np.float32, copy=True)
        self.eta_g = float(eta_g)
        self.round = 0

    def apply_mean(self, updates: list[Update], scale: float | None = None):
        """Eq. 6:  w ← w − η_g/|S| Σ g̃."""
        s = self.eta_g / len(updates) if scale is None else scale
        acc = np.zeros_like(self.w)
        for u in updates:
            add_update(acc, u)
        self.w -= s * acc
        self.round += 1


# ----------------------------------------------------------------- sanitizer
@dataclasses.dataclass
class SanitizerConfig:
    """Knobs for `UpdateSanitizer`.

    nonfinite_guard — reject updates containing NaN/Inf (corrupted wire
        payloads, diverged local training).
    clip_norm — L2 outlier guard: updates with ‖u‖₂ > clip_norm are
        rescaled to that norm (None disables). Note the norm is taken
        over the payload's stored values, so a sparse (values, indices)
        payload and its dense form can differ in the last float bit —
        keep clipping out of bitwise engine-equivalence comparisons.
    tau_max — staleness cap: arrivals with τ > tau_max are dropped
        (`stale_mode="drop"`) or scaled by 1/(1 + τ − τ_max)
        (`stale_mode="downweight"`). None disables.
    """
    nonfinite_guard: bool = True
    clip_norm: float | None = None
    tau_max: int | None = None
    stale_mode: str = "drop"          # drop | downweight


def _scaled(a: Arrival, w: float) -> Arrival:
    u = a.update
    if isinstance(u, SparseUpdate):
        u = SparseUpdate(u.values * np.float32(w), u.indices, u.dim, u.kept)
    else:
        u = u * np.float32(w)
    return dataclasses.replace(a, update=u)


class UpdateSanitizer:
    """Admission control for arrivals; counts what it rejects/reshapes."""

    def __init__(self, cfg: SanitizerConfig | None = None):
        self.cfg = cfg or SanitizerConfig()
        # sanitized_dropped counts outright rejections (a clipped or
        # down-weighted update is modified, not dropped)
        self.counts = {"sanitized_nonfinite": 0, "sanitized_stale": 0,
                       "sanitized_clipped": 0, "sanitized_dropped": 0}

    def admit(self, tau: int, a: Arrival) -> Arrival | None:
        """Admitted (possibly rescaled) arrival, or None when dropped."""
        cfg = self.cfg
        vals = a.update.values if isinstance(a.update, SparseUpdate) \
            else a.update
        if cfg.nonfinite_guard and not bool(np.all(np.isfinite(vals))):
            self.counts["sanitized_nonfinite"] += 1
            self.counts["sanitized_dropped"] += 1
            return None
        if cfg.tau_max is not None and tau > cfg.tau_max:
            self.counts["sanitized_stale"] += 1
            if cfg.stale_mode == "drop":
                self.counts["sanitized_dropped"] += 1
                return None
            a = _scaled(a, 1.0 / (1.0 + (tau - cfg.tau_max)))
            vals = a.update.values if isinstance(a.update, SparseUpdate) \
                else a.update
        if cfg.clip_norm is not None:
            nrm = float(np.linalg.norm(vals))
            if nrm > cfg.clip_norm:
                self.counts["sanitized_clipped"] += 1
                a = _scaled(a, cfg.clip_norm / nrm)
        return a


# --------------------------------------------------------------------- mixins
class _Base:
    def __init__(self, model: GlobalModel):
        self.model = model
        self.total_bits = 0.0
        self.staleness_log: list[int] = []
        self.sanitizer: UpdateSanitizer | None = None

    def _tau(self, a: Arrival) -> int:
        return max(0, self.model.round - a.model_round)

    def _admit(self, a: Arrival) -> Arrival | None:
        """Charge wire bits, then run the sanitizer (if any)."""
        self.total_bits += a.wire_bits
        if self.sanitizer is None:
            return a
        return self.sanitizer.admit(self._tau(a), a)

    def on_arrival(self, t_now: float, a: Arrival) -> list[AggregationEvent]:
        raise NotImplementedError

    def on_round_boundary(self, t_now: float) -> list[AggregationEvent]:
        return []


class PeriodicAggregator(_Base):
    """AFL with periodic aggregation (FedPer / FedLuck servers are identical;
    FedLuck differs only in the (k_i, δ_i) plans devices run with)."""

    def __init__(self, model: GlobalModel):
        super().__init__(model)
        self.buffer: list[Arrival] = []
        self.rejected: list[int] = []   # sanitizer-dropped senders to release

    def on_arrival(self, t_now, a):
        adm = self._admit(a)
        if adm is None:
            self.rejected.append(a.device_id)
            return []
        self.buffer.append(adm)
        return []

    def on_round_boundary(self, t_now):
        rejected, self.rejected = self.rejected, []
        if not self.buffer:
            self.model.round += 1  # empty round still advances the period
            return [AggregationEvent(t_now, self.model.round,
                                     sorted(set(rejected)), {})]
        # τ counts the round being FORMED: a device that trained on w^t and
        # lands in the aggregation producing w^{t+k} has τ = k = ⌈d_i/T̃⌉
        # (the equivalence the φ-solver relies on, paper Sec. 2.2).
        stale = {a.device_id: self._tau(a) + 1 for a in self.buffer}
        self.staleness_log.extend(stale.values())
        self.model.apply_mean([a.update for a in self.buffer])
        release = [a.device_id for a in self.buffer]
        release += sorted(set(rejected) - set(release))
        ev = AggregationEvent(t_now, self.model.round, release, stale)
        self.buffer = []
        return [ev]


class BufferedAggregator(_Base):
    """FedBuff: aggregate whenever `buffer_size` gradients are buffered."""

    def __init__(self, model: GlobalModel, buffer_size: int = 3):
        super().__init__(model)
        self.K = buffer_size
        self.buffer: list[Arrival] = []

    def on_arrival(self, t_now, a):
        adm = self._admit(a)
        if adm is None:
            return []   # simulator's buffered fallback restarts the sender
        self.buffer.append(adm)
        if len(self.buffer) < self.K:
            return []
        stale = {x.device_id: self._tau(x) for x in self.buffer}
        self.staleness_log.extend(stale.values())
        self.model.apply_mean([x.update for x in self.buffer])
        ev = AggregationEvent(t_now, self.model.round,
                              [x.device_id for x in self.buffer], stale)
        self.buffer = []
        return [ev]


class AsyncAggregator(_Base):
    """FedAsync: apply immediately with polynomial staleness weight
    s(τ) = (1+τ)^(-a)  (Xie et al. 2019)."""

    def __init__(self, model: GlobalModel, poly_a: float = 0.5,
                 mix_eta: float = 0.8):
        super().__init__(model)
        self.poly_a = poly_a
        self.mix_eta = mix_eta

    def on_arrival(self, t_now, a):
        a = self._admit(a)
        if a is None:
            return []   # simulator's buffered fallback restarts the sender
        tau = self._tau(a)
        self.staleness_log.append(tau)
        weight = self.mix_eta * (1.0 + tau) ** (-self.poly_a)
        if isinstance(a.update, SparseUpdate):
            np.subtract.at(self.model.w, a.update.indices,
                           (self.model.eta_g * weight) * a.update.values)
        else:
            self.model.w -= self.model.eta_g * weight * a.update
        self.model.round += 1
        return [AggregationEvent(t_now, self.model.round, [a.device_id],
                                 {a.device_id: tau})]


class SyncAggregator(_Base):
    """FedAvg(+TopK): barrier across all N devices; optional straggler
    deadline (ft: drop updates arriving > deadline after round start)."""

    def __init__(self, model: GlobalModel, num_devices: int,
                 deadline: float | None = None):
        super().__init__(model)
        self.N = num_devices
        self.deadline = deadline
        self.buffer: list[Arrival] = []
        self.rejected: list[int] = []
        self.round_start = 0.0
        self.expected: set[int] | None = None

    def begin_round(self, t_now: float, device_ids: list[int]):
        self.round_start = t_now
        self.expected = set(device_ids)

    def on_arrival(self, t_now, a):
        adm = self._admit(a)
        if adm is None:
            # sanitizer rejection: the update is dropped (bits were spent)
            # but the sender must still be released at the barrier or the
            # next round can never complete
            self.expected.discard(a.device_id)
            self.rejected.append(a.device_id)
        elif (self.deadline is not None
                and t_now - self.round_start > self.deadline):
            # straggler mitigation: too late, drop (bits were still spent)
            self.expected.discard(a.device_id)
        else:
            self.buffer.append(adm)
            self.expected.discard(a.device_id)
        if self.expected:
            return []
        stale = {x.device_id: self._tau(x) for x in self.buffer}
        self.staleness_log.extend(stale.values())
        if self.buffer:
            self.model.apply_mean([x.update for x in self.buffer])
        else:
            self.model.round += 1
        release = [x.device_id for x in self.buffer] + list(
            stale.keys() - {x.device_id for x in self.buffer})
        ev = AggregationEvent(t_now, self.model.round,
                              sorted({*release, *stale, *self.rejected}),
                              stale)
        self.buffer = []
        self.rejected = []
        return [ev]


def make_aggregator(name: str, model: GlobalModel, *, num_devices: int = 0,
                    **kw) -> _Base:
    name = name.lower()
    if name in ("periodic", "fedper", "fedluck"):
        return PeriodicAggregator(model)
    if name == "fedbuff":
        return BufferedAggregator(model, **kw)
    if name == "fedasync":
        return AsyncAggregator(model, **kw)
    if name in ("sync", "fedavg", "fedavg_topk"):
        return SyncAggregator(model, num_devices, **kw)
    raise ValueError(f"unknown aggregator {name}")
