"""Gradient compressors (paper Sec 2.2: top-k sparsification, rate δ = k/d).

All compressors are jit-safe pure functions over *flat* fp32 vectors plus
pytree adapters. Each returns a `Compressed` carrying enough to (a) exactly
reconstruct the dense update and (b) account wire bytes the way the paper
does (tx time ∝ δ·β → bytes = nnz·(value+index)).

Error feedback (EF/EF21-style residual accumulation) is a wrapper usable
with any compressor; the paper's plain top-k is `topk` with EF disabled.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class Compressed(NamedTuple):
    """Sparse/quantized payload. `dense()` is exact reconstruction."""
    values: jax.Array          # [k] or [d] (quantizers)
    indices: jax.Array | None  # [k] int32 or None (dense codes)
    dim: int                   # original flat dim d
    wire_bits: jax.Array       # scalar — bits on the wire
    meta: Any = None

    def dense(self) -> jax.Array:
        if self.indices is None:
            return self.values
        out = jnp.zeros((self.dim,), self.values.dtype)
        return out.at[self.indices].add(self.values)


CompressFn = Callable[[jax.Array], Compressed]


# ---------------------------------------------------------------------- utils
def flatten_pytree(tree) -> tuple[jax.Array, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = [l.shape for l in leaves]
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves]) \
        if leaves else jnp.zeros((0,), jnp.float32)
    return flat, (treedef, shapes, [l.dtype for l in leaves])


def unflatten_pytree(flat: jax.Array, spec) -> Any:
    treedef, shapes, dtypes = spec
    leaves, pos = [], 0
    for shp, dt in zip(shapes, dtypes):
        n = int(np.prod(shp)) if shp else 1
        leaves.append(flat[pos:pos + n].reshape(shp).astype(dt))
        pos += n
    return jax.tree_util.tree_unflatten(treedef, leaves)


def num_keep(dim: int, rate: float) -> int:
    """δ = k/d (paper's definition); always keep at least 1."""
    return max(1, min(dim, int(round(rate * dim))))


# -------------------------------------------------------------- wire payload
HEADER_BITS = 32   # i32 kept-count header of the compact wire format

# Compressors whose payload ships as the compact (values, indices, count)
# wire format rather than a dense code.
SPARSE_WIRE = ("topk", "topk_threshold", "randk")


def sparse_wire(name: str, dim: int, rate: float) -> bool:
    """True when `name`'s payload ships compact: explicit (values, indices)
    plus a kept-count header. A δ = 1 top-k ships dense — its index vector
    would be a d-length iota and the payload IS the vector."""
    return name in SPARSE_WIRE and num_keep(dim, rate) < dim


def payload_bits(cc: Compressed) -> jax.Array:
    """Bits of `cc` as actually shipped: the compressor's strict value/index
    bits plus the kept-count header compact payloads carry."""
    return cc.wire_bits + (HEADER_BITS if cc.indices is not None else 0)


# ----------------------------------------------------------------- compressors
def topk(g: jax.Array, rate: float) -> Compressed:
    """Paper's compressor C_δ: keep the δ·d largest-|g| coordinates."""
    d = g.shape[0]
    k = num_keep(d, rate)
    _, idx = jax.lax.top_k(jnp.abs(g), k)
    vals = g[idx]
    bits = jnp.asarray(k * (32 + 32), jnp.float32)  # fp32 value + int32 index
    return Compressed(vals, idx.astype(jnp.int32), d, bits)


def topk_capped(g: jax.Array, k: jax.Array, *, k_cap: int) -> Compressed:
    """Top-k with a *traced* per-call k bounded by the static `k_cap`.

    Built for `jax.vmap` over a bucket of devices whose δ_i (and hence
    k_i = δ_i·d) differ: the payload always has `k_cap` slots, with entries
    beyond k zero-valued (their indices are real top-|g| coordinates, but
    scatter-adding a 0 is a no-op, so `dense()` reconstructs exactly the
    top-k selection). Because `lax.top_k` sorts descending with
    index-order tie-breaks, the first k of the top-k_cap equal the exact
    top-k — bitwise identical to `topk(g, k/d)`.
    """
    d = g.shape[0]
    _, idx = jax.lax.top_k(jnp.abs(g), k_cap)
    keep = jnp.arange(k_cap) < k
    vals = jnp.where(keep, g[idx], 0.0)
    bits = jnp.asarray(k, jnp.float32) * (32.0 + 32.0)
    return Compressed(vals, idx.astype(jnp.int32), d, bits)


def randk(g: jax.Array, rate: float, key: jax.Array) -> Compressed:
    d = g.shape[0]
    k = num_keep(d, rate)
    idx = jax.random.choice(key, d, (k,), replace=False)
    scale = d / k  # unbiased
    return Compressed(g[idx] * scale, idx.astype(jnp.int32), d,
                      jnp.asarray(k * 64, jnp.float32))


def qsgd(g: jax.Array, levels: int = 256) -> Compressed:
    """QSGD stochastic quantization (dense code, log2(levels)+sign bits/coord)."""
    d = g.shape[0]
    norm = jnp.linalg.norm(g) + 1e-12
    scaled = jnp.abs(g) / norm * (levels - 1)
    lower = jnp.floor(scaled)
    # deterministic rounding variant for reproducibility under jit
    q = jnp.where(scaled - lower > 0.5, lower + 1, lower)
    vals = jnp.sign(g) * q * norm / (levels - 1)
    bits_per = np.log2(levels) + 1
    return Compressed(vals, None, d, jnp.asarray(d * bits_per + 32, jnp.float32))


def signsgd(g: jax.Array) -> Compressed:
    scale = jnp.mean(jnp.abs(g))
    return Compressed(jnp.sign(g) * scale, None, g.shape[0],
                      jnp.asarray(g.shape[0] * 1 + 32, jnp.float32))


def terngrad(g: jax.Array, key: jax.Array) -> Compressed:
    s = jnp.max(jnp.abs(g)) + 1e-12
    p = jnp.abs(g) / s
    b = jax.random.bernoulli(key, p).astype(jnp.float32)
    return Compressed(jnp.sign(g) * b * s, None, g.shape[0],
                      jnp.asarray(g.shape[0] * np.log2(3) + 32, jnp.float32))


def identity(g: jax.Array) -> Compressed:
    return Compressed(g, None, g.shape[0],
                      jnp.asarray(g.shape[0] * 32, jnp.float32))


# -------------------------------------------------------- threshold top-k (TPU)
def _bracket_threshold(counts_ge: jax.Array, edges: jax.Array, k) -> tuple:
    """(lo, hi) bracket: largest edge with count >= k and the edge above it.
    Mirrors `kernels.ops._solve_threshold` (edges descending)."""
    reached = counts_ge >= k
    sel = jnp.argmax(reached)
    sel = jnp.where(jnp.any(reached), sel, edges.shape[0] - 1)
    return edges[sel], edges[jnp.maximum(sel - 1, 0)]


def topk_threshold(g: jax.Array, rate: float, *, coarse_buckets: int = 48,
                   fine_buckets: int = 128,
                   exact_k: bool | None = None) -> Compressed:
    """TPU-native top-k: log-magnitude histogram → threshold → mask.

    Pure-jnp reference of the Pallas `magnitude_hist` + `ef_topk` pipeline
    (see repro/kernels), parameter-compatible with `kernels.ops.topk_compress`
    (same coarse log2 pass + fine linear pass and the same defaults).
    Selection matches exact top-k up to ties at the threshold; nnz is capped
    to k exactly by a final count-based correction. Returns a *dense masked*
    payload (indices=None) — the wire cost is still accounted sparse
    (k values + k indices), matching how the compacted form would ship.
    """
    d = g.shape[0]
    k = num_keep(d, rate)
    mag = jnp.abs(g)
    gmax = jnp.max(mag) + 1e-30
    # pass 1: coarse histogram over log2 magnitude relative to max
    coarse_edges = gmax * 2.0 ** (-jnp.arange(coarse_buckets + 1,
                                              dtype=jnp.float32))  # descending
    c_counts = jnp.sum(mag[None, :] >= coarse_edges[:, None], axis=1)
    lo_t, hi_t = _bracket_threshold(c_counts, coarse_edges, k)
    # pass 2: fine linear histogram inside [lo_t, hi_t]
    frac = jnp.arange(fine_buckets + 1, dtype=jnp.float32) / fine_buckets
    fine_edges = jnp.maximum(hi_t - (hi_t - lo_t) * frac, 1e-30)  # descending
    f_counts = jnp.sum(mag[None, :] >= fine_edges[:, None], axis=1)
    _, t = _bracket_threshold(f_counts, fine_edges, k)
    mask = mag >= t
    # exact-k correction: if count > k, drop smallest of the selected (ties).
    # Skipped for d beyond int32 (lax.top_k index limit) — there the bisection
    # resolution alone bounds the overshoot.
    if exact_k is None:
        exact_k = d < 2 ** 31
    if exact_k:
        cnt = jnp.sum(mask)

        def drop_extra(mask):
            # rank selected magnitudes; keep top-k among them
            key = jnp.where(mask, mag, -jnp.inf)
            _, keep_idx = jax.lax.top_k(key, k)
            m = jnp.zeros((d,), jnp.bool_).at[keep_idx].set(True)
            return m

        mask = jax.lax.cond(cnt > k, drop_extra, lambda m: m, mask)
    vals = jnp.where(mask, g, 0.0)
    bits = jnp.asarray(k * 64, jnp.float32)
    return Compressed(vals, None, d, bits, meta={"threshold": t})


# --------------------------------------------------------------- error feedback
@dataclasses.dataclass(frozen=True)
class Compressor:
    """Named compressor with δ baked in; uniform callable interface."""
    name: str
    rate: float  # δ (1.0 for dense codes)
    fn: Callable[..., Compressed]
    needs_key: bool = False

    def __call__(self, g: jax.Array, key: jax.Array | None = None) -> Compressed:
        if self.needs_key:
            if key is None:
                key = jax.random.PRNGKey(0)
            return self.fn(g, key)
        return self.fn(g)


def make_compressor(name: str, rate: float = 1.0, **kw) -> Compressor:
    if name == "topk":
        return Compressor("topk", rate, partial(topk, rate=rate))
    if name == "topk_threshold":
        return Compressor("topk_threshold", rate,
                          partial(topk_threshold, rate=rate, **kw))
    if name == "randk":
        return Compressor("randk", rate, partial(randk, rate=rate), needs_key=True)
    if name == "qsgd":
        return Compressor("qsgd", 1.0, partial(qsgd, **kw))
    if name == "signsgd":
        return Compressor("signsgd", 1.0, signsgd)
    if name == "terngrad":
        return Compressor("terngrad", 1.0, terngrad, needs_key=True)
    if name in ("identity", "none"):
        return Compressor("identity", 1.0, identity)
    raise ValueError(f"unknown compressor {name}")


def ef_compress(compressor: Compressor, g: jax.Array, residual: jax.Array,
                key: jax.Array | None = None) -> tuple[Compressed, jax.Array]:
    """Error-feedback: compress (g + residual), keep what was dropped."""
    acc = g + residual
    comp = compressor(acc, key)
    new_residual = acc - comp.dense()
    return comp, new_residual
