"""FedLuck controller: profiles devices, solves Eq. 15, re-plans elastically.

Implements Alg. 1 lines 1–5 / 15–18: devices measure α_i (avg seconds per
local step) and β_i (seconds to ship a *full* gradient); the controller
minimizes the key convergence factor φ per device. It also owns the
*elastic* path: when membership changes (join/leave/failure) or measured
α/β drift beyond `replan_tolerance`, plans are recomputed — the datacenter
driver and the AFL simulator both call into this.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

from repro.core.factor import Plan, solve_plan, solve_plan_fixed_delta, \
    solve_plan_fixed_k


@dataclasses.dataclass
class DeviceProfile:
    """Measured/derived capabilities of one device (or pod)."""
    device_id: int
    alpha: float            # seconds per local step
    beta: float             # seconds to transmit one FULL gradient (δ=1)
    bandwidth_bps: float = 0.0   # informational

    @staticmethod
    def from_bandwidth(device_id: int, alpha: float, model_bits: float,
                       bandwidth_bps: float) -> "DeviceProfile":
        return DeviceProfile(device_id, alpha, model_bits / bandwidth_bps,
                             bandwidth_bps)


def profile_alpha(step_fn: Callable[[], None], warmup: int = 2,
                  iters: int = 5) -> float:
    """Measure seconds per local step by running the real jitted step."""
    for _ in range(warmup):
        step_fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        step_fn()
    return (time.perf_counter() - t0) / iters


def derive_alpha_from_roofline(flops_per_step: float, hbm_bytes: float,
                               peak_flops: float, hbm_bw: float) -> float:
    """Dry-run path: α from the compiled roofline (max of the two terms)."""
    return max(flops_per_step / peak_flops, hbm_bytes / hbm_bw)


@dataclasses.dataclass
class FedLuckController:
    round_period: float                     # T̃ seconds
    k_bounds: tuple[int, int] = (1, 60)
    delta_bounds: tuple[float, float] = (1e-3, 1.0)
    mode: str = "joint"                     # joint | fixed_delta | fixed_k
    fixed_delta: float = 0.01               # for 'Opt. LF' baseline
    fixed_k: int = 10                       # for 'Opt. CR' baseline
    replan_tolerance: float = 0.25          # re-plan if α/β drift > 25%

    def __post_init__(self):
        self._profiles: dict[int, DeviceProfile] = {}
        self._plans: dict[int, Plan] = {}
        self.replans = 0   # drift-triggered re-solves (not first registration)

    # ------------------------------------------------------------- membership
    def register(self, profile: DeviceProfile) -> Plan:
        self._profiles[profile.device_id] = profile
        plan = self._solve(profile)
        self._plans[profile.device_id] = plan
        return plan

    def deregister(self, device_id: int) -> None:
        """Device failure / scale-down: drop it; remaining plans are
        per-device so they stay valid (φ couples devices only through T̃)."""
        self._profiles.pop(device_id, None)
        self._plans.pop(device_id, None)

    def update_profile(self, profile: DeviceProfile) -> Plan:
        """Drift-aware re-plan (straggler turning slower, link congestion)."""
        old = self._profiles.get(profile.device_id)
        self._profiles[profile.device_id] = profile
        if old is not None:
            drift = max(abs(profile.alpha - old.alpha) / max(old.alpha, 1e-12),
                        abs(profile.beta - old.beta) / max(old.beta, 1e-12))
            if drift <= self.replan_tolerance and profile.device_id in self._plans:
                return self._plans[profile.device_id]
        if old is not None:
            self.replans += 1
        plan = self._solve(profile)
        self._plans[profile.device_id] = plan
        return plan

    # ------------------------------------------------------------------ solve
    def _solve(self, p: DeviceProfile) -> Plan:
        if self.mode == "joint":
            return solve_plan(p.alpha, p.beta, self.round_period,
                              self.k_bounds, self.delta_bounds)
        if self.mode == "fixed_delta":   # optimize LF only (Opt. LF)
            return solve_plan_fixed_delta(p.alpha, p.beta, self.round_period,
                                          self.fixed_delta, self.k_bounds)
        if self.mode == "fixed_k":       # optimize CR only (Opt. CR)
            return solve_plan_fixed_k(p.alpha, p.beta, self.round_period,
                                      self.fixed_k, self.delta_bounds)
        raise ValueError(f"unknown mode {self.mode}")

    def plan(self, device_id: int) -> Plan:
        return self._plans[device_id]

    def plans(self) -> dict[int, Plan]:
        return dict(self._plans)

    # ------------------------------------------------------------ diagnostics
    def max_staleness(self) -> int:
        return max((p.staleness for p in self._plans.values()), default=0)

    def summary(self) -> str:
        rows = [f"  dev {i}: k={p.k:3d} δ={p.delta:.4f} φ={p.phi:.3f} "
                f"τ={p.staleness}" for i, p in sorted(self._plans.items())]
        return "\n".join(rows)
