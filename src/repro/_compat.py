"""Back-port shims for newer jax APIs onto the pinned toolchain (0.4.37).

The codebase (and the dist test suite) is written against the current jax
sharding surface: `jax.sharding.AxisType`, `jax.make_mesh(..., axis_types=)`,
`jax.set_mesh`, and top-level `jax.shard_map` with `axis_names=`/`check_vma=`.
The container pins jax 0.4.37, which predates all four. `install()` adds the
missing attributes — it only ever fills gaps (every patch is hasattr-guarded),
so on a newer jax it is a no-op and the native implementations win.

Semantics notes for the back-ports:
  - AxisType.Auto is the only mode this repo uses; on 0.4.37 every mesh axis
    is GSPMD-auto under jit, so accepting-and-dropping `axis_types` is exact.
  - `set_mesh(mesh)` returns the mesh itself, which is already a context
    manager, so `with jax.set_mesh(mesh): ...` scopes the resource env the
    same way the new global-mesh API does for this repo's usage.
  - `shard_map(..., axis_names=S, check_vma=v)` maps onto the classic
    `jax.experimental.shard_map.shard_map(f, mesh, ..., check_rep=v,
    auto=mesh_axes - S)`, resolving the mesh from the ambient resource env
    when not passed explicitly.
"""
from __future__ import annotations

import enum
import functools
import inspect

import jax


class _AxisType(enum.Enum):
    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


def _context_mesh():
    from jax._src.mesh import thread_resources
    m = thread_resources.env.physical_mesh
    if m.empty:
        raise ValueError(
            "shard_map shim: no mesh in context — pass mesh= explicitly or "
            "wrap the call in `with mesh:` / `with jax.set_mesh(mesh):`")
    return m


def _shard_map(f, *, mesh=None, in_specs, out_specs, axis_names=None,
               check_vma=True):
    from jax.experimental.shard_map import shard_map as _sm
    m = mesh if mesh is not None else _context_mesh()
    kw = {"check_rep": bool(check_vma)}
    if axis_names is not None:
        auto = frozenset(m.axis_names) - frozenset(axis_names)
        if auto:
            kw["auto"] = auto
    return _sm(f, m, in_specs=in_specs, out_specs=out_specs, **kw)


def install() -> None:
    if not hasattr(jax.sharding, "AxisType"):
        jax.sharding.AxisType = _AxisType

    if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        _native_make_mesh = jax.make_mesh

        @functools.wraps(_native_make_mesh)
        def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kw):
            del axis_types  # 0.4.37: every axis is GSPMD-auto under jit
            return _native_make_mesh(axis_shapes, axis_names, **kw)

        jax.make_mesh = make_mesh

    if not hasattr(jax, "set_mesh"):
        jax.set_mesh = lambda mesh: mesh  # Mesh is itself a context manager

    if not hasattr(jax, "shard_map"):
        jax.shard_map = _shard_map
