import numpy as np
import pytest

from repro.core.aggregation import (Arrival, AsyncAggregator,
                                    BufferedAggregator, GlobalModel,
                                    PeriodicAggregator, SanitizerConfig,
                                    SparseUpdate, SyncAggregator,
                                    UpdateSanitizer, make_aggregator)


def _arr(did, vec, rnd, t, bits=100.0):
    return Arrival(did, np.asarray(vec, np.float32), rnd, bits, t)


class TestPeriodic:
    def test_buffers_until_boundary_then_eq6(self):
        m = GlobalModel(np.zeros(3), eta_g=1.0)
        agg = PeriodicAggregator(m)
        assert agg.on_arrival(0.3, _arr(0, [1, 0, 0], 0, 0.3)) == []
        assert agg.on_arrival(0.7, _arr(1, [0, 2, 0], 0, 0.7)) == []
        evs = agg.on_round_boundary(1.0)
        # w ← w − η_g/|S| Σ g̃  = −([1,0,0]+[0,2,0])/2
        np.testing.assert_allclose(m.w, [-0.5, -1.0, 0.0])
        assert m.round == 1
        assert sorted(evs[0].release_to) == [0, 1]

    def test_empty_round_still_advances(self):
        m = GlobalModel(np.zeros(2))
        agg = PeriodicAggregator(m)
        agg.on_round_boundary(1.0)
        assert m.round == 1
        np.testing.assert_allclose(m.w, 0.0)


class TestBuffered:
    def test_triggers_at_k(self):
        m = GlobalModel(np.zeros(2))
        agg = BufferedAggregator(m, buffer_size=3)
        assert agg.on_arrival(0.1, _arr(0, [3, 0], 0, 0.1)) == []
        assert agg.on_arrival(0.2, _arr(1, [0, 3], 0, 0.2)) == []
        evs = agg.on_arrival(0.3, _arr(2, [3, 3], 0, 0.3))
        assert len(evs) == 1
        np.testing.assert_allclose(m.w, [-2.0, -2.0])


class TestAsync:
    def test_staleness_weight_poly(self):
        m = GlobalModel(np.zeros(1), eta_g=1.0)
        agg = AsyncAggregator(m, poly_a=0.5, mix_eta=1.0)
        agg.on_arrival(0.1, _arr(0, [1.0], 0, 0.1))    # τ=0 → weight 1
        np.testing.assert_allclose(m.w, [-1.0])
        # next arrival computed against round 0, but model is at round 1
        agg.on_arrival(0.2, _arr(1, [1.0], 0, 0.2))    # τ=1 → 2^-0.5
        np.testing.assert_allclose(m.w, [-1.0 - 2 ** -0.5])

    def test_staleness_logged(self):
        m = GlobalModel(np.zeros(1))
        agg = AsyncAggregator(m)
        agg.on_arrival(0.1, _arr(0, [1.0], 0, 0.1))
        agg.on_arrival(0.2, _arr(1, [1.0], 0, 0.2))
        assert agg.staleness_log == [0, 1]


class TestSync:
    def test_barrier_waits_for_all(self):
        m = GlobalModel(np.zeros(1))
        agg = SyncAggregator(m, num_devices=2)
        agg.begin_round(0.0, [0, 1])
        assert agg.on_arrival(0.5, _arr(0, [2.0], 0, 0.5)) == []
        evs = agg.on_arrival(0.9, _arr(1, [4.0], 0, 0.9))
        assert len(evs) == 1
        np.testing.assert_allclose(m.w, [-3.0])

    def test_deadline_drops_straggler(self):
        m = GlobalModel(np.zeros(1))
        agg = SyncAggregator(m, num_devices=2, deadline=1.0)
        agg.begin_round(0.0, [0, 1])
        agg.on_arrival(0.5, _arr(0, [2.0], 0, 0.5))
        evs = agg.on_arrival(5.0, _arr(1, [100.0], 0, 5.0))  # too late
        assert len(evs) == 1
        np.testing.assert_allclose(m.w, [-2.0])  # straggler excluded


class TestSanitizer:
    def test_nonfinite_rejected(self):
        san = UpdateSanitizer(SanitizerConfig())
        assert san.admit(0, _arr(0, [1.0, np.nan], 0, 0.1)) is None
        assert san.admit(0, _arr(0, [np.inf, 0.0], 0, 0.1)) is None
        ok = san.admit(0, _arr(0, [1.0, 2.0], 0, 0.1))
        np.testing.assert_allclose(ok.update, [1.0, 2.0])
        assert san.counts["sanitized_nonfinite"] == 2
        assert san.counts["sanitized_dropped"] == 2

    def test_nonfinite_sparse_payload(self):
        san = UpdateSanitizer(SanitizerConfig())
        u = SparseUpdate(np.asarray([np.nan], np.float32),
                         np.asarray([1], np.int32), 4)
        assert san.admit(0, Arrival(0, u, 0, 10.0, 0.1)) is None

    def test_clip_rescales_to_norm(self):
        san = UpdateSanitizer(SanitizerConfig(clip_norm=1.0))
        a = san.admit(0, _arr(0, [3.0, 4.0], 0, 0.1))   # ‖u‖ = 5
        np.testing.assert_allclose(a.update, [0.6, 0.8], rtol=1e-6)
        assert san.counts["sanitized_clipped"] == 1
        assert san.counts["sanitized_dropped"] == 0     # modified, not dropped
        b = san.admit(0, _arr(0, [0.3, 0.4], 0, 0.1))   # under the cap
        np.testing.assert_allclose(b.update, [0.3, 0.4])
        assert san.counts["sanitized_clipped"] == 1

    def test_tau_max_drop_and_downweight(self):
        drop = UpdateSanitizer(SanitizerConfig(tau_max=2))
        assert drop.admit(3, _arr(0, [1.0], 0, 0.1)) is None
        assert drop.admit(2, _arr(0, [1.0], 0, 0.1)) is not None
        assert drop.counts["sanitized_stale"] == 1
        assert drop.counts["sanitized_dropped"] == 1

        dw = UpdateSanitizer(SanitizerConfig(tau_max=2,
                                             stale_mode="downweight"))
        a = dw.admit(4, _arr(0, [3.0], 0, 0.1))   # τ−τ_max = 2 → 1/3
        np.testing.assert_allclose(a.update, [1.0], rtol=1e-6)
        assert dw.counts["sanitized_dropped"] == 0

    def test_periodic_releases_rejected_sender(self):
        """A sanitizer-dropped device must still get the next model — a
        silent drop would deadlock its training loop forever."""
        m = GlobalModel(np.zeros(2))
        agg = PeriodicAggregator(m)
        agg.sanitizer = UpdateSanitizer(SanitizerConfig())
        agg.on_arrival(0.3, _arr(0, [np.nan, 0.0], 0, 0.3))
        agg.on_arrival(0.7, _arr(1, [0.0, 2.0], 0, 0.7))
        evs = agg.on_round_boundary(1.0)
        assert sorted(evs[0].release_to) == [0, 1]
        np.testing.assert_allclose(m.w, [0.0, -2.0])  # only dev 1 admitted
        assert agg.total_bits == 200.0  # rejected upload still paid its bits

    def test_sync_releases_rejected_sender(self):
        m = GlobalModel(np.zeros(1))
        agg = SyncAggregator(m, num_devices=2)
        agg.sanitizer = UpdateSanitizer(SanitizerConfig())
        agg.begin_round(0.0, [0, 1])
        agg.on_arrival(0.5, _arr(0, [np.nan], 0, 0.5))
        evs = agg.on_arrival(0.9, _arr(1, [4.0], 0, 0.9))
        assert sorted(evs[0].release_to) == [0, 1]
        np.testing.assert_allclose(m.w, [-4.0])


def test_factory():
    m = GlobalModel(np.zeros(1))
    assert isinstance(make_aggregator("fedluck", m), PeriodicAggregator)
    assert isinstance(make_aggregator("fedbuff", m, buffer_size=2),
                      BufferedAggregator)
    assert isinstance(make_aggregator("fedasync", m), AsyncAggregator)
    assert isinstance(make_aggregator("fedavg_topk", m, num_devices=3),
                      SyncAggregator)
    with pytest.raises(ValueError):
        make_aggregator("nope", m)
