"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import compression as C
from repro.core.aggregation import Arrival, GlobalModel, PeriodicAggregator
from repro.core.factor import phi, solve_plan
from repro.data.partition import dirichlet_partition, iid_partition

_dims = st.integers(min_value=8, max_value=2000)
_rates = st.floats(min_value=1e-3, max_value=1.0)
_seeds = st.integers(min_value=0, max_value=2 ** 16)


@settings(max_examples=25, deadline=None)
@given(d=_dims, rate=_rates, seed=_seeds)
def test_topk_nnz_never_exceeds_budget(d, rate, seed):
    g = jnp.asarray(np.random.RandomState(seed).randn(d).astype(np.float32))
    comp = C.topk(g, rate)
    k = C.num_keep(d, rate)
    assert comp.values.shape[0] == k
    assert int(np.count_nonzero(np.asarray(comp.dense()))) <= k


@settings(max_examples=25, deadline=None)
@given(d=_dims, rate=_rates, seed=_seeds)
def test_ef_conservation_invariant(d, rate, seed):
    """∀ g, r:  C(g+r).dense() + r' == g + r  (error feedback loses nothing)."""
    rng = np.random.RandomState(seed)
    g = jnp.asarray(rng.randn(d).astype(np.float32))
    r = jnp.asarray(rng.randn(d).astype(np.float32) * 0.3)
    comp, new_r = C.ef_compress(C.make_compressor("topk", rate), g, r)
    np.testing.assert_allclose(np.asarray(comp.dense() + new_r),
                               np.asarray(g + r), rtol=1e-4, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(d=_dims, rate=_rates, seed=_seeds)
def test_compression_never_increases_norm(d, rate, seed):
    g = jnp.asarray(np.random.RandomState(seed).randn(d).astype(np.float32))
    comp = C.topk(g, rate)
    assert float(jnp.linalg.norm(comp.dense())) \
        <= float(jnp.linalg.norm(g)) + 1e-5


@settings(max_examples=20, deadline=None)
@given(alpha=st.floats(0.001, 1.0), beta=st.floats(0.1, 100.0),
       k=st.integers(1, 50), delta=st.floats(1e-3, 1.0))
def test_solver_dominates_random_point(alpha, beta, k, delta):
    """φ(plan) ≤ φ(any feasible point) — Eq. 15 optimality."""
    plan = solve_plan(alpha, beta, 1.0, k_bounds=(1, 50),
                      delta_bounds=(1e-3, 1.0))
    assert plan.phi <= phi(k, delta, alpha, beta, 1.0) * 1.005


@settings(max_examples=20, deadline=None)
@given(n=st.integers(40, 400), clients=st.integers(2, 8), seed=_seeds)
def test_iid_partition_is_exact_cover(n, clients, seed):
    parts = iid_partition(n, clients, seed)
    allidx = np.concatenate(parts)
    assert len(allidx) == n
    assert len(np.unique(allidx)) == n


@settings(max_examples=10, deadline=None)
@given(clients=st.integers(2, 6), seed=_seeds)
def test_dirichlet_partition_is_exact_cover(clients, seed):
    labels = np.random.RandomState(seed).randint(0, 5, 600)
    parts = dirichlet_partition(labels, clients, alpha=1.0, seed=seed)
    allidx = np.concatenate(parts)
    assert len(allidx) == 600
    assert len(np.unique(allidx)) == 600


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 6), seed=_seeds)
def test_periodic_aggregation_is_mean_update(n, seed):
    """Eq. 6: the global update equals −η_g · mean(updates)."""
    rng = np.random.RandomState(seed)
    w0 = rng.randn(16).astype(np.float32)
    m = GlobalModel(w0, eta_g=0.7)
    agg = PeriodicAggregator(m)
    ups = [rng.randn(16).astype(np.float32) for _ in range(n)]
    for i, u in enumerate(ups):
        agg.on_arrival(0.1 * i, Arrival(i, u, 0, 1.0, 0.1 * i))
    agg.on_round_boundary(1.0)
    np.testing.assert_allclose(m.w, w0 - 0.7 * np.mean(ups, axis=0),
                               rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(d=st.integers(64, 4096), rate=st.floats(0.01, 0.5), seed=_seeds)
def test_threshold_pipeline_matches_ef_invariant(d, rate, seed):
    """The Pallas pipeline obeys the same conservation law as the oracle."""
    from repro.kernels import ops
    rng = np.random.RandomState(seed)
    g = jnp.asarray(rng.randn(d).astype(np.float32))
    r = jnp.asarray(rng.randn(d).astype(np.float32) * 0.2)
    out, new_r, nnz, _ = ops.topk_compress(g, r, rate=rate, block=1024,
                                           interpret=True)
    np.testing.assert_allclose(np.asarray(out + new_r), np.asarray(g + r),
                               rtol=1e-5, atol=1e-5)
    assert float(nnz) <= C.num_keep(d, rate) + 1
