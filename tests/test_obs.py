"""Unit tests for the repro.obs subsystem: histogram bucketing, registry
semantics, tracer records, Perfetto export schema, and validator failure
modes — plus an end-to-end instrumented mini-simulation."""
import json

import pytest

from repro.obs import (CONTROLLER_TRACK, NULL_TRACER, SERVER_TRACK,
                       Histogram, MetricsRegistry, NullTracer,
                       PerfettoExporter, PhaseTimers, Tracer, device_track,
                       validate_chrome_trace, validate_metrics_json)


class TestHistogram:
    def test_bucket_edges_inclusive_upper(self):
        """Bucket i counts bounds[i-1] < v <= bounds[i]."""
        h = Histogram((0, 1, 2, 4))
        for v in (-1.0, 0.0):
            h.observe(v)          # v <= 0 -> bucket 0
        h.observe(1.0)            # 0 < v <= 1 -> bucket 1
        h.observe(1.5)            # 1 < v <= 2 -> bucket 2
        h.observe(4.0)            # 2 < v <= 4 -> bucket 3
        h.observe(100.0)          # overflow
        assert h.counts == [2, 1, 1, 1, 1]
        assert h.count == sum(h.counts) == 6
        assert h.mean() == pytest.approx((-1 + 0 + 1 + 1.5 + 4 + 100) / 6)

    def test_overflow_bucket_exists(self):
        h = Histogram((1,))
        assert len(h.counts) == 2
        h.observe(2.0)
        assert h.counts == [0, 1]

    def test_rejects_non_increasing_bounds(self):
        with pytest.raises(ValueError):
            Histogram((1, 1, 2))
        with pytest.raises(ValueError):
            Histogram(())


class TestRegistry:
    def test_get_or_make_and_snapshot(self):
        m = MetricsRegistry()
        m.counter("sim.a").inc()
        m.counter("sim.a").inc(2.0)
        m.gauge("engine.g").set(7)
        m.histogram("sim.h", (1, 2)).observe(1.5)
        snap = m.snapshot()
        assert snap["counters"]["sim.a"] == 3.0
        assert snap["gauges"]["engine.g"] == 7.0
        assert snap["histograms"]["sim.h"]["counts"] == [0, 1, 0]

    def test_histogram_needs_bounds_on_first_use(self):
        m = MetricsRegistry()
        with pytest.raises(ValueError):
            m.histogram("h")
        m.histogram("h", (1,))
        assert m.histogram("h") is m.histogram("h", (5,))  # bounds ignored

    def test_engine_agnostic_strips_engine_and_time(self):
        m = MetricsRegistry()
        m.counter("sim.x").inc()
        m.counter("engine.y").inc()
        m.counter("time.z_s").inc()
        snap = m.snapshot(engine_agnostic=True)
        assert set(snap["counters"]) == {"sim.x"}

    def test_merge_totals_overwrites(self):
        m = MetricsRegistry()
        m.counter("faults.drops_total").inc(99)
        m.merge_totals("faults.", {"drops_total": 3, "retries": 5})
        snap = m.snapshot()
        assert snap["counters"]["faults.drops_total"] == 3.0
        assert snap["counters"]["faults.retries"] == 5.0


class TestTracer:
    def test_span_and_instant_records(self):
        tr = Tracer()
        tr.span(device_track(2), "local_round", 1.0, 3.0, k=4)
        tr.instant(SERVER_TRACK, "arrival", 3.0, device=2)
        assert len(tr) == 2
        e = tr.by_name("local_round")[0]
        assert e.ph == "X" and e.ts == 1.0 and e.dur == 2.0
        assert e.arg("k") == 4 and e.arg("missing", -1) == -1
        assert tr.tracks() == [device_track(2), SERVER_TRACK]
        tr.clear()
        assert len(tr) == 0

    def test_null_tracer_records_nothing(self):
        tr = NullTracer()
        tr.span(SERVER_TRACK, "x", 0, 1)
        tr.instant(CONTROLLER_TRACK, "y", 0)
        assert len(tr) == 0 and not tr.enabled
        assert not NULL_TRACER.enabled

    def test_events_are_order_sensitive_and_comparable(self):
        a, b = Tracer(), Tracer()
        a.instant("t", "e1", 0.0)
        a.instant("t", "e2", 0.0)
        b.instant("t", "e2", 0.0)
        b.instant("t", "e1", 0.0)
        assert a.events != b.events
        assert sorted(a.events, key=str) == sorted(b.events, key=str)


class TestPhaseTimers:
    def test_phase_accumulates(self):
        tm = PhaseTimers()
        with tm.phase("p"):
            pass
        with tm.phase("p"):
            pass
        tm.add("q", 1.5)
        snap = tm.snapshot()
        assert snap["p"]["calls"] == 2 and snap["p"]["seconds"] >= 0
        assert snap["q"] == {"seconds": 1.5, "calls": 1}

    def test_export_to_metrics(self):
        tm = PhaseTimers()
        tm.add("drain", 2.0)
        m = MetricsRegistry()
        tm.export_to(m)
        snap = m.snapshot()
        assert snap["counters"]["time.drain_s"] == 2.0
        assert snap["counters"]["time.drain_calls"] == 1.0


class TestPerfettoSchema:
    def _trace(self):
        tr = Tracer()
        tr.span(device_track(0), "local_round", 0.0, 0.5, k=2)
        tr.span(device_track(1), "upload", 0.5, 0.7)
        tr.instant(SERVER_TRACK, "arrival", 0.7, device=1)
        tr.instant(CONTROLLER_TRACK, "replan", 0.8, device=0)
        return tr

    def test_required_keys_on_every_event(self):
        doc = PerfettoExporter().to_chrome(self._trace())
        for e in doc["traceEvents"]:
            for key in ("ph", "ts", "pid", "tid", "name"):
                assert key in e, (key, e)

    def test_track_layout_and_units(self):
        doc = PerfettoExporter().to_chrome(self._trace())
        info = validate_chrome_trace(doc)
        assert info["events"] == 4
        assert info["device_tracks"] == ["device 0", "device 1"]
        assert set(info["tracks"].values()) == {
            "server", "controller", "device 0", "device 1"}
        span = next(e for e in doc["traceEvents"]
                    if e["name"] == "local_round")
        assert span["ph"] == "X"
        assert span["ts"] == 0.0 and span["dur"] == pytest.approx(0.5e6)
        inst = next(e for e in doc["traceEvents"] if e["name"] == "arrival")
        assert inst["ph"] == "i" and inst["s"] == "t"
        assert inst["args"] == {"device": 1}

    def test_export_roundtrip(self, tmp_path):
        path = str(tmp_path / "trace.json")
        PerfettoExporter().export(self._trace(), path)
        info = validate_chrome_trace(path)
        assert info["events"] == 4
        with open(path) as f:
            assert json.load(f)["traceEvents"]

    def test_validator_rejects_missing_keys(self):
        with pytest.raises(ValueError, match="missing required key"):
            validate_chrome_trace({"traceEvents": [
                {"ph": "X", "ts": 0, "pid": 1, "name": "x"}]})

    def test_validator_rejects_unknown_phase_and_unlabelled_tid(self):
        meta = {"ph": "M", "ts": 0, "pid": 1, "tid": 5,
                "name": "thread_name", "args": {"name": "t"}}
        with pytest.raises(ValueError, match="unknown phase"):
            validate_chrome_trace({"traceEvents": [
                meta, {"ph": "Z", "ts": 0, "pid": 1, "tid": 5, "name": "x"}]})
        with pytest.raises(ValueError, match="no thread_name"):
            validate_chrome_trace({"traceEvents": [
                {"ph": "i", "ts": 0, "pid": 1, "tid": 6, "name": "x"}]})

    def test_validator_rejects_metadata_only(self):
        with pytest.raises(ValueError, match="only metadata"):
            validate_chrome_trace({"traceEvents": [
                {"ph": "M", "ts": 0, "pid": 1, "tid": 0,
                 "name": "process_name", "args": {"name": "p"}}]})


class TestMetricsJson:
    def test_roundtrip_validates(self, tmp_path):
        m = MetricsRegistry()
        m.counter("sim.cycles").inc(4)
        m.histogram("sim.staleness", (0, 1, 2)).observe(1)
        path = str(tmp_path / "metrics.json")
        doc = m.to_json(path, extra={"engine": "batched"})
        assert doc["schema"] == "repro.obs.metrics/v1"
        assert validate_metrics_json(path)["engine"] == "batched"

    def test_multi_engine_layout(self):
        m = MetricsRegistry()
        m.counter("sim.cycles").inc()
        doc = {"schema": "repro.obs.metrics/v1",
               "batched": m.snapshot(), "sequential": m.snapshot()}
        validate_metrics_json(doc)

    def test_rejects_histogram_count_mismatch(self):
        doc = {"counters": {}, "gauges": {}, "histograms": {
            "h": {"bounds": [1], "counts": [1, 2], "count": 5, "sum": 0}}}
        with pytest.raises(ValueError, match="do not sum"):
            validate_metrics_json(doc)

    def test_rejects_wrong_bucket_arity(self):
        doc = {"counters": {}, "gauges": {}, "histograms": {
            "h": {"bounds": [1, 2], "counts": [1, 1], "count": 2, "sum": 0}}}
        with pytest.raises(ValueError, match="len"):
            validate_metrics_json(doc)


class TestEndToEnd:
    def test_instrumented_mini_sim_trace_validates(self, tmp_path):
        """A tiny instrumented run exports a loadable trace with per-device
        tracks plus server metadata, and a valid metrics snapshot."""
        from repro.core.controller import DeviceProfile
        from repro.core.factor import Plan
        from repro.core.simulator import AFLSimulator, DeviceSpec
        from repro.models.small import make_task

        task = make_task("mlp_micro", num_samples=200, test_samples=60,
                         batch_size=16)
        specs = []
        for did in range(2):
            p = DeviceProfile(did, 0.02 * (1 + did), 2.0)
            specs.append(DeviceSpec(p, Plan(2, 0.2, 0.0,
                                            2 * p.alpha + 0.2 * p.beta, 1),
                                    "topk", did == 0))
        tracer, metrics = Tracer(), MetricsRegistry()
        sim = AFLSimulator(task, specs, "periodic", round_period=1.0, seed=0,
                           engine="batched", tracer=tracer, metrics=metrics)
        hist = sim.run(total_rounds=3, eval_every=1)
        sim.close()

        trace_path = str(tmp_path / "trace.json")
        PerfettoExporter().export(tracer, trace_path)
        info = validate_chrome_trace(trace_path)
        assert info["device_tracks"] == ["device 0", "device 1"]
        assert info["events"] == len(tracer)
        assert tracer.by_name("local_round") and tracer.by_name("eval")

        metrics_path = str(tmp_path / "metrics.json")
        metrics.to_json(metrics_path)
        validate_metrics_json(metrics_path)
        snap = metrics.snapshot()
        assert snap["counters"]["sim.cycles"] > 0
        for k, v in hist.counters.items():
            assert snap["counters"][f"faults.{k}"] == float(v)
        # per-eval-window staleness counts ride on Record.window
        assert any("staleness_counts" in r.window for r in hist.records)
