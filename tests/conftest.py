# Tests run on the default single CPU device. Do NOT set
# xla_force_host_platform_device_count here — only launch/dryrun.py (and the
# dist subprocess tests) use the 512-device placeholder mesh.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
