import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree


@pytest.fixture
def tree():
    return {"params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                       "b": jnp.ones(4, jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}


class TestSaveLoad:
    def test_roundtrip(self, tree, tmp_path):
        d = str(tmp_path / "ck")
        save_pytree(tree, d)
        back = load_pytree(d, like=tree)
        np.testing.assert_allclose(np.asarray(back["params"]["w"]),
                                   np.asarray(tree["params"]["w"]))
        assert back["params"]["b"].dtype == jnp.bfloat16
        assert int(back["step"]) == 7

    def test_atomic_overwrite(self, tree, tmp_path):
        d = str(tmp_path / "ck")
        save_pytree(tree, d)
        tree2 = {**tree, "step": jnp.asarray(8, jnp.int32)}
        save_pytree(tree2, d)
        assert int(load_pytree(d, like=tree)["step"]) == 8
        assert not os.path.exists(d + ".tmp")

    def test_missing_key_raises(self, tree, tmp_path):
        d = str(tmp_path / "ck")
        save_pytree({"params": tree["params"]}, d)
        with pytest.raises(KeyError):
            load_pytree(d, like=tree)


class TestManager:
    def test_retention_and_latest(self, tree, tmp_path):
        mgr = CheckpointManager(str(tmp_path), max_to_keep=2,
                                async_save=False)
        for s in [10, 20, 30]:
            mgr.save(s, tree)
        assert mgr.steps() == [20, 30]
        assert mgr.latest_step() == 30

    def test_async_save_then_restore(self, tree, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=True)
        mgr.save(5, tree)
        mgr.wait()
        back = mgr.restore(like=tree)
        assert int(back["step"]) == 7

    def test_restore_empty_returns_none(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        assert mgr.restore() is None

    def test_restart_resumes_training(self, tmp_path):
        """Crash/restart contract used by launch/train.py."""
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        w = np.arange(5, dtype=np.float32)
        mgr.save(3, {"w": w, "round": np.asarray(3)})
        # "crash"; new process restores
        mgr2 = CheckpointManager(str(tmp_path))
        state = mgr2.restore(like={"w": w, "round": np.asarray(0)})
        assert int(state["round"]) == 3
        np.testing.assert_allclose(state["w"], w)
