import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree


@pytest.fixture
def tree():
    return {"params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                       "b": jnp.ones(4, jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}


class TestSaveLoad:
    def test_roundtrip(self, tree, tmp_path):
        d = str(tmp_path / "ck")
        save_pytree(tree, d)
        back = load_pytree(d, like=tree)
        np.testing.assert_allclose(np.asarray(back["params"]["w"]),
                                   np.asarray(tree["params"]["w"]))
        assert back["params"]["b"].dtype == jnp.bfloat16
        assert int(back["step"]) == 7

    def test_atomic_overwrite(self, tree, tmp_path):
        d = str(tmp_path / "ck")
        save_pytree(tree, d)
        tree2 = {**tree, "step": jnp.asarray(8, jnp.int32)}
        save_pytree(tree2, d)
        assert int(load_pytree(d, like=tree)["step"]) == 8
        assert not os.path.exists(d + ".tmp")

    def test_missing_key_raises(self, tree, tmp_path):
        d = str(tmp_path / "ck")
        save_pytree({"params": tree["params"]}, d)
        with pytest.raises(KeyError):
            load_pytree(d, like=tree)


class TestManager:
    def test_retention_and_latest(self, tree, tmp_path):
        mgr = CheckpointManager(str(tmp_path), max_to_keep=2,
                                async_save=False)
        for s in [10, 20, 30]:
            mgr.save(s, tree)
        assert mgr.steps() == [20, 30]
        assert mgr.latest_step() == 30

    def test_async_save_then_restore(self, tree, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=True)
        mgr.save(5, tree)
        mgr.wait()
        back = mgr.restore(like=tree)
        assert int(back["step"]) == 7

    def test_restore_empty_returns_none(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        assert mgr.restore() is None

    def test_restart_resumes_training(self, tmp_path):
        """Crash/restart contract used by launch/train.py."""
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        w = np.arange(5, dtype=np.float32)
        mgr.save(3, {"w": w, "round": np.asarray(3)})
        # "crash"; new process restores
        mgr2 = CheckpointManager(str(tmp_path))
        state = mgr2.restore(like={"w": w, "round": np.asarray(0)})
        assert int(state["round"]) == 3
        np.testing.assert_allclose(state["w"], w)


class TestFLResume:
    """Checkpoint/resume of an error-feedback FL run must reproduce the
    uninterrupted run: the per-device EF residuals are part of the training
    state (issue: they were silently dropped, so a resumed run re-dropped
    every deferred coordinate)."""

    @staticmethod
    def _sim():
        from repro.core.controller import DeviceProfile
        from repro.core.factor import Plan
        from repro.core.simulator import AFLSimulator, DeviceSpec
        from repro.models.small import make_task

        # batch_size >= client subset size -> every local batch is the full
        # (order-permuted) subset, so the dynamics are loader-state-free and
        # a resumed run is comparable to the uninterrupted one.
        task = make_task("mlp_fmnist", num_samples=64, test_samples=32,
                         batch_size=64)
        specs = [
            DeviceSpec(DeviceProfile(i, 0.01 * (i + 1), 2.0 + i),
                       Plan(2, 0.1, 0.0, 0.02 * (i + 1) + 0.1 * (2.0 + i), 0),
                       "topk", True)
            for i in range(2)]
        return AFLSimulator(task, specs, "periodic", round_period=1.0,
                            eta_l=0.05, seed=0)

    def test_resume_with_error_feedback_matches_uninterrupted(self, tmp_path):
        from repro.launch.train import fl_ckpt_state, restore_fl_state

        sim_a = self._sim()
        sim_a.run(total_rounds=8, eval_every=0)

        sim_b = self._sim()
        sim_b.run(total_rounds=4, eval_every=0)
        state = fl_ckpt_state(sim_b)
        assert np.abs(state["residuals"]).sum() > 0  # EF is really deferring
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        mgr.save(int(state["round"]), state)

        sim_c = self._sim()
        restore_fl_state(sim_c, mgr.restore(mgr.latest_step()))
        assert sim_c.model.round == sim_b.model.round
        sim_c.run(total_rounds=8, eval_every=0)
        np.testing.assert_allclose(sim_c.model.w, sim_a.model.w,
                                   rtol=0, atol=2e-4)

        # restoring w/round but NOT the residuals (the old bug) diverges
        sim_d = self._sim()
        restore_fl_state(sim_d, {"w": state["w"], "round": state["round"]})
        sim_d.run(total_rounds=8, eval_every=0)
        err_with = np.abs(sim_c.model.w - sim_a.model.w).max()
        err_without = np.abs(sim_d.model.w - sim_a.model.w).max()
        assert err_without > max(err_with * 10, 1e-6)
