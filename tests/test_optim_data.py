"""Coverage for the optimizer and data substrates."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataLoader
from repro.data.synthetic import (SyntheticClassification, SyntheticSpeech,
                                  SyntheticTokens, make_task_dataset)
from repro.optim import (adamw, apply_updates, constant_schedule,
                         cosine_schedule, momentum_sgd, sgd, warmup_cosine)


class TestOptimizers:
    def _tree(self, seed=0):
        rng = np.random.RandomState(seed)
        return {"a": jnp.asarray(rng.randn(8, 4).astype(np.float32)),
                "b": jnp.asarray(rng.randn(16).astype(np.float32))}

    def test_sgd_matches_hand_math(self):
        p = {"w": jnp.asarray([1.0, 2.0])}
        g = {"w": jnp.asarray([0.5, -1.0])}
        opt = sgd(0.1)
        st = opt.init(p)
        p2, _ = opt.update(g, st, p)
        np.testing.assert_allclose(np.asarray(p2["w"]), [0.95, 2.1])

    def test_momentum_accumulates(self):
        """Two identical grads: second step moves (1 + momentum)× the first."""
        p = self._tree()
        g = jax.tree.map(jnp.ones_like, p)
        opt = momentum_sgd(0.1, momentum=0.9)
        st = opt.init(p)
        p1, st = opt.update(g, st, p)
        d1 = float(jnp.sum(jnp.abs(p["a"] - p1["a"])))
        p2, st = opt.update(g, st, p1)
        d2 = float(jnp.sum(jnp.abs(p1["a"] - p2["a"])))
        assert np.isclose(d2 / d1, 1.9, rtol=1e-5)

    def test_adamw_step_size_bounded_by_lr(self):
        p = self._tree(1)
        g = jax.tree.map(lambda x: x * 3.0, p)
        opt = adamw(1e-2)
        st = opt.init(p)
        p2, _ = opt.update(g, st, p)
        delta = jax.tree.map(lambda a, b: jnp.max(jnp.abs(a - b)), p, p2)
        # |Δ| ≤ lr / (1 - eps-ish) on step 1 for adam
        assert all(float(d) <= 1.1e-2 for d in jax.tree.leaves(delta))

    def test_optimizers_descend_quadratic(self):
        target = jnp.asarray([3.0, -2.0, 0.5])
        loss = lambda p: jnp.sum((p["x"] - target) ** 2)
        for opt in (sgd(0.1), momentum_sgd(0.05), adamw(0.1)):
            p = {"x": jnp.zeros(3)}
            st = opt.init(p)
            for _ in range(100):
                g = jax.grad(loss)(p)
                p, st = opt.update(g, st, p)
            assert float(loss(p)) < 1e-2, opt.name

    def test_apply_updates_sign(self):
        p = {"w": jnp.ones(3)}
        u = {"w": jnp.ones(3)}
        out = apply_updates(p, u, scale=-0.5)   # Eq. 6 style
        np.testing.assert_allclose(np.asarray(out["w"]), 0.5)


class TestSchedules:
    def test_constant(self):
        s = constant_schedule(0.3)
        assert float(s(jnp.asarray(100))) == pytest.approx(0.3)

    def test_cosine_endpoints(self):
        s = cosine_schedule(1.0, total_steps=100, final_frac=0.1)
        assert float(s(jnp.asarray(0))) == pytest.approx(1.0)
        assert float(s(jnp.asarray(100))) == pytest.approx(0.1)

    def test_warmup_ramps(self):
        s = warmup_cosine(1.0, warmup=10, total_steps=110)
        assert float(s(jnp.asarray(5))) == pytest.approx(0.5)
        assert float(s(jnp.asarray(10))) == pytest.approx(1.0, abs=1e-3)


class TestData:
    def test_loader_covers_epoch_without_repeats(self):
        ds = SyntheticClassification(num_samples=96)
        dl = DataLoader(ds, batch_size=32, seed=0)
        seen = []
        for _ in range(3):
            b = dl.next()
            seen.append(b["label"])
        assert sum(len(s) for s in seen) == 96

    def test_loader_infinite(self):
        ds = SyntheticTokens(vocab=64, seq_len=16, num_samples=40)
        dl = DataLoader(ds, batch_size=16, seed=0)
        for _ in range(10):
            b = dl.next()
            assert b["tokens"].shape == (16, 15)

    def test_task_factory(self):
        assert isinstance(make_task_dataset("fmnist"), SyntheticClassification)
        assert isinstance(make_task_dataset("sc"), SyntheticSpeech)
        with pytest.raises(ValueError):
            make_task_dataset("nope")

    def test_train_test_share_task_but_not_samples(self):
        tr = SyntheticClassification(num_samples=64, seed=3, sample_seed=0)
        te = SyntheticClassification(num_samples=64, seed=3, sample_seed=1)
        np.testing.assert_allclose(tr.prototypes, te.prototypes)
        assert not np.allclose(tr.images, te.images)

    def test_speech_shapes(self):
        ds = SyntheticSpeech(num_samples=8, seq_len=12, features=5)
        b = ds.batch(np.arange(4))
        assert b["frames"].shape == (4, 12, 5)
