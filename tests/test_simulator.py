import numpy as np
import pytest

from repro.core.controller import DeviceProfile, FedLuckController
from repro.core.simulator import (AFLSimulator, DeviceSpec,
                                  STRATEGY_FOR_METHOD,
                                  make_heterogeneous_devices, plan_devices)
from repro.core.factor import Plan
from repro.models.small import make_task


@pytest.fixture(scope="module")
def task():
    return make_task("mlp_fmnist", num_samples=1200, test_samples=300,
                     batch_size=32)


def _profiles(n=4, model_bits=3.2e6):
    return make_heterogeneous_devices(n, model_bits, base_alpha=0.02, seed=0)


class TestPlanning:
    def test_heterogeneous_devices_get_distinct_plans(self):
        profs = _profiles(6)
        specs = plan_devices(profs, "fedluck", round_period=1.0)
        ks = {s.plan.k for s in specs}
        ds = {round(s.plan.delta, 5) for s in specs}
        assert len(ks) > 1 or len(ds) > 1  # heterogeneity → different plans

    def test_fedper_uniform(self):
        specs = plan_devices(_profiles(4), "fedper", 1.0, fixed_k=7,
                             fixed_delta=0.2)
        assert all(s.plan.k == 7 and s.plan.delta == 0.2 for s in specs)

    def test_uncompressed_baselines_full_rate(self):
        specs = plan_devices(_profiles(4), "fedasync", 1.0, fixed_k=5)
        assert all(s.rate == 1.0 for s in specs)


class TestSimulation:
    def test_fedluck_converges(self, task):
        specs = plan_devices(_profiles(4), "fedluck", 1.0, k_bounds=(1, 10))
        sim = AFLSimulator(task, specs, "periodic", round_period=1.0,
                           eta_l=0.05, seed=0)
        h = sim.run(total_rounds=15, eval_every=3)
        assert h.final_accuracy() > 0.8
        assert h.records[-1].gbits > 0

    def test_time_and_bits_accounting(self, task):
        """Default accounting charges the actual payload shape — k values +
        k indices + the kept-count header per upload (the compact wire
        format); wire_accounting="analytic" restores the paper's rate·d·32
        estimate."""
        from repro.core import compression as C
        profs = _profiles(2)
        plan = Plan(3, 0.125, 0.0, 1.0, 1)
        specs = [DeviceSpec(p, plan, "topk") for p in profs]
        sim = AFLSimulator(task, specs, "periodic", round_period=10.0,
                           seed=0)
        sim.run(total_rounds=1, eval_every=1)
        d = sim.dim
        per_upload = C.num_keep(d, 0.125) * 64 + C.HEADER_BITS
        total = sim.agg.total_bits
        assert total > 0 and total % per_upload == 0
        sim.close()

        sim2 = AFLSimulator(task, [DeviceSpec(p, plan, "topk")
                                   for p in _profiles(2)],
                            "periodic", round_period=10.0, seed=0,
                            wire_accounting="analytic")
        sim2.run(total_rounds=1, eval_every=1)
        total2 = sim2.agg.total_bits
        assert total2 > 0 and total2 % (0.125 * d * 32) == 0
        sim2.close()

    def test_staleness_matches_ceil_formula(self, task):
        """τ = ceil(d_i / T̃) for a device slower than the round period."""
        prof = DeviceProfile(0, alpha=0.5, beta=2.0)   # d = 3·0.5+1·2=3.5
        plan = Plan(3, 1.0, 0.0, 3.5, 4)
        spec = DeviceSpec(prof, plan, "none")
        sim = AFLSimulator(task, [spec], "periodic", round_period=1.0,
                           seed=0)
        sim.run(total_rounds=9, eval_every=0)
        stal = [s for s in sim.agg.staleness_log if s > 0]
        assert stal and max(stal) == int(np.ceil(3.5 / 1.0))

    @pytest.mark.parametrize("method", ["fedper", "fedbuff", "fedasync",
                                        "fedavg_topk"])
    def test_all_baselines_run(self, task, method):
        specs = plan_devices(_profiles(3), method, 1.0, fixed_k=3,
                             fixed_delta=0.1)
        kw = {"strategy_kwargs": {"buffer_size": 2}} \
            if method == "fedbuff" else {}
        sim = AFLSimulator(task, specs, STRATEGY_FOR_METHOD[method],
                           round_period=1.0, seed=0, **kw)
        h = sim.run(total_rounds=8, eval_every=4)
        assert len(h.records) >= 1
        assert np.isfinite(h.final_accuracy())


class TestController:
    def test_elastic_membership(self):
        ctl = FedLuckController(round_period=1.0)
        p1 = ctl.register(DeviceProfile(0, 0.02, 10.0))
        ctl.register(DeviceProfile(1, 0.08, 30.0))
        assert ctl.max_staleness() >= 0
        ctl.deregister(1)
        assert list(ctl.plans()) == [0]
        assert ctl.plan(0) == p1

    def test_replan_on_drift(self):
        ctl = FedLuckController(round_period=1.0, replan_tolerance=0.25)
        p0 = ctl.register(DeviceProfile(0, 0.02, 10.0))
        same = ctl.update_profile(DeviceProfile(0, 0.021, 10.0))  # 5% drift
        assert same is p0       # below tolerance: cached plan, no re-solve
        assert ctl.replans == 0
        new = ctl.update_profile(DeviceProfile(0, 0.2, 10.0))     # 10x drift
        assert ctl.replans == 1
        assert new.k < p0.k     # slower α → fewer local steps fit the period
        # the re-solved plan becomes the new cache baseline
        assert ctl.update_profile(DeviceProfile(0, 0.21, 10.0)) is new
        assert ctl.replans == 1

    def test_replan_counts_beta_drift(self):
        ctl = FedLuckController(round_period=1.0, replan_tolerance=0.25)
        ctl.register(DeviceProfile(0, 0.02, 10.0))
        ctl.update_profile(DeviceProfile(0, 0.02, 30.0))  # 3× slower link
        assert ctl.replans == 1

    def test_modes_match_table2_baselines(self):
        prof = DeviceProfile(0, 0.05, 25.0)
        cr = FedLuckController(1.0, mode="fixed_k", fixed_k=12)
        lf = FedLuckController(1.0, mode="fixed_delta", fixed_delta=0.05)
        assert cr.register(prof).k == 12
        assert lf.register(prof).delta == 0.05


class TestFinalRecordTime:
    def test_heap_drain_final_record_is_finite(self, task):
        """sync strategy with deadline=0 drops the only arrival and releases
        nobody -> the event heap drains before total_rounds with the default
        max_sim_time=inf; the closing History record must carry the last
        processed event time, not inf."""
        import math

        prof = DeviceProfile(0, alpha=0.1, beta=1.0)
        plan = Plan(2, 1.0, 0.0, 1.2, 0)
        spec = DeviceSpec(prof, plan, "none")
        sim = AFLSimulator(task, [spec], "sync",
                           strategy_kwargs={"deadline": 0.0})
        h = sim.run(total_rounds=5, eval_every=1)
        assert h.records
        assert all(math.isfinite(r.time) for r in h.records)
        assert h.records[-1].time > 0.0
