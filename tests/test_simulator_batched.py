"""Batched engine regression tests: the vmapped bucket dispatcher must be
*bitwise* indistinguishable from the sequential reference path — model
weights, EF residuals, wire-bit accounting, and record timelines all equal
on a mixed-k / mixed-δ fleet."""
import numpy as np
import pytest

from repro.core.controller import DeviceProfile
from repro.core.factor import Plan
from repro.core.simulator import (AFLSimulator, DeviceSpec, _chunk_sizes,
                                  plan_devices, make_heterogeneous_devices)
from repro.models.small import make_task


@pytest.fixture(scope="module")
def task():
    return make_task("mlp_micro", num_samples=600, test_samples=120,
                     batch_size=16)


def _mixed_fleet():
    """4 devices: mixed k (three share k=2 → multi-row chunk + a singleton),
    mixed δ including a full-rate (δ=1) device, EF on two of them."""
    cfg = [  # (did, k, delta, ef)
        (0, 2, 0.05, True),
        (1, 5, 1.0, False),
        (2, 2, 0.2, True),
        (3, 2, 1.0, False),
    ]
    out = []
    for did, k, delta, ef in cfg:
        p = DeviceProfile(did, 0.01 * (1 + did), 2.0)
        rt = k * p.alpha + delta * p.beta
        out.append(DeviceSpec(p, Plan(k, delta, 0.0, rt, 1), "topk", ef))
    return out


def _run(task, engine, *, count_index_bits=False, strategy="periodic",
         rounds=6):
    sim = AFLSimulator(task, _mixed_fleet(), strategy, round_period=1.0,
                       seed=3, engine=engine,
                       count_index_bits=count_index_bits)
    h = sim.run(total_rounds=rounds, eval_every=2)
    ids, res = sim.residual_snapshot()
    out = {
        "w": np.asarray(sim.model.w).copy(),
        "res": np.asarray(res).copy(),
        "bits": sim.agg.total_bits,
        "records": [(r.time, r.round, r.accuracy, r.loss, r.gbits,
                     r.mean_staleness) for r in h.records],
        "events": sim.events_processed,
    }
    sim.close()
    return out


class TestEngineEquivalence:
    def test_bitwise_equal_periodic(self, task):
        b = _run(task, "batched")
        s = _run(task, "sequential")
        assert np.array_equal(b["w"], s["w"])
        assert np.array_equal(b["res"], s["res"])
        assert b["bits"] == s["bits"]
        assert b["records"] == s["records"]
        assert b["events"] == s["events"]

    def test_bitwise_equal_strict_bits(self, task):
        """count_index_bits=True routes the per-compressor strict wire-bit
        values through the vmapped dispatch — they must match exactly."""
        b = _run(task, "batched", count_index_bits=True, rounds=4)
        s = _run(task, "sequential", count_index_bits=True, rounds=4)
        assert b["bits"] == s["bits"] > 0
        assert np.array_equal(b["w"], s["w"])

    def test_residuals_accumulate(self, task):
        b = _run(task, "batched")
        assert float(np.abs(b["res"][0]).sum()) > 0   # EF device row moved
        assert float(np.abs(b["res"][1]).sum()) == 0  # non-EF row untouched

    def test_fedbuff_strategy_equivalent(self, task):
        b = _run(task, "batched", strategy="fedbuff", rounds=4)
        s = _run(task, "sequential", strategy="fedbuff", rounds=4)
        assert np.array_equal(b["w"], s["w"])
        assert b["records"] == s["records"]


class TestChunking:
    def test_chunk_sizes_exact_pow2_cover(self):
        for n in range(1, 70):
            sizes = _chunk_sizes(n)
            assert sum(sizes) == n
            assert all(s & (s - 1) == 0 for s in sizes)  # powers of two

    def test_failure_schedule_keeps_batched_engine(self, task):
        """Fault-injected runs no longer fall back to the sequential path."""
        from repro.ft import FailureSchedule
        fs = FailureSchedule.random(4, 10.0, seed=0)
        sim = AFLSimulator(task, _mixed_fleet(), "periodic",
                           failure_schedule=fs, engine="batched")
        assert sim._batched
        sim.close()


def _fault_run(task, engine, *, rounds=8, strategy="periodic",
               channel=False, sanitizer=False, controller=False,
               prefetch=0):
    """Run a failure-injected mixed fleet; fresh stateful fault models per
    call so batched/sequential consume identical RNG streams."""
    from repro.core.aggregation import SanitizerConfig
    from repro.core.controller import FedLuckController
    from repro.ft import (BandwidthDrift, FailureSchedule, LossyChannel,
                          StragglerDrift)
    kwargs = {"failure_schedule": FailureSchedule.random(
        4, 12.0, rate_per_device=1.0, mean_downtime=0.6, seed=4)}
    if channel:
        kwargs["channel"] = LossyChannel(
            loss_prob=0.3, corrupt_prob=0.1,
            drift=[BandwidthDrift(1, 2.0, 3.0)], seed=7)
        # NaN-corrupted payloads must be sanitized out — otherwise the
        # model itself goes NaN and bitwise comparison is meaningless
        sanitizer = True
    if sanitizer:
        kwargs["sanitizer"] = SanitizerConfig(tau_max=8)
    if controller:
        kwargs["controller"] = FedLuckController(1.0, (1, 8), (0.05, 1.0))
        kwargs["stragglers"] = [StragglerDrift(2, 3.0, 4.0)]
    sim = AFLSimulator(task, _mixed_fleet(), strategy, round_period=1.0,
                       seed=3, engine=engine, prefetch=prefetch, **kwargs)
    h = sim.run(total_rounds=rounds, eval_every=2)
    _, res = sim.residual_snapshot()
    out = {
        "w": np.asarray(sim.model.w).copy(),
        "res": np.asarray(res).copy(),
        "bits": sim.agg.total_bits,
        "records": [(r.time, r.round, r.accuracy, r.loss, r.gbits,
                     r.mean_staleness, r.drops) for r in h.records],
        "events": sim.events_processed,
        "counters": dict(h.counters),
    }
    sim.close()
    return out


class TestFaultEquivalence:
    """Acceptance gate: a failure-injected mixed-k/δ/EF fleet is *bitwise*
    identical across engines — crashes, lossy links, retries, drift,
    sanitization, and mid-run re-plans all included."""

    def test_crash_injected_bitwise_equal(self, task):
        b = _fault_run(task, "batched")
        s = _fault_run(task, "sequential")
        assert b["counters"]["crash_lost"] > 0   # faults actually fired
        assert np.array_equal(b["w"], s["w"])
        assert np.array_equal(b["res"], s["res"])
        assert b["bits"] == s["bits"]
        assert b["records"] == s["records"]
        assert b["events"] == s["events"]
        assert b["counters"] == s["counters"]

    def test_chaos_bitwise_equal(self, task):
        """Crash windows + lossy/corrupting channel + bandwidth drift +
        sanitizer, all at once."""
        b = _fault_run(task, "batched", channel=True)
        s = _fault_run(task, "sequential", channel=True)
        assert b["counters"]["retries"] > 0
        assert b["counters"]["drops_total"] > 0
        assert np.array_equal(b["w"], s["w"])
        assert np.array_equal(b["res"], s["res"])
        assert b["records"] == s["records"]
        assert b["counters"] == s["counters"]

    def test_drift_replan_bitwise_equal(self, task):
        """Straggler drift feeding a controller re-plans k mid-run in both
        engines at the same events."""
        b = _fault_run(task, "batched", controller=True)
        s = _fault_run(task, "sequential", controller=True)
        assert np.array_equal(b["w"], s["w"])
        assert b["records"] == s["records"]
        assert b["counters"] == s["counters"]

    def test_prefetch_bitwise_equal_across_replan(self, task):
        """StackedLoader prefetch>0 must produce the SAME batch sequence as
        the synchronous path — per-step-batch queueing makes the worker
        k-agnostic, so a mid-run controller re-plan (set_k) re-stacks
        without flushing and nothing diverges."""
        base = _fault_run(task, "batched", controller=True)
        pre = _fault_run(task, "batched", controller=True, prefetch=2)
        assert base["counters"]["replans"] > 0   # a re-plan actually fired
        assert np.array_equal(base["w"], pre["w"])
        assert np.array_equal(base["res"], pre["res"])
        assert base["bits"] == pre["bits"]
        assert base["records"] == pre["records"]
        assert base["counters"] == pre["counters"]

    def test_fedbuff_crash_bitwise_equal(self, task):
        b = _fault_run(task, "batched", strategy="fedbuff", rounds=5)
        s = _fault_run(task, "sequential", strategy="fedbuff", rounds=5)
        assert np.array_equal(b["w"], s["w"])
        assert b["records"] == s["records"]


def _obs_fault_run(task, engine, *, obs=True, channel=True, controller=True,
                   rounds=8):
    """Fault-injected fleet with (optionally) a Tracer + MetricsRegistry
    attached; returns weights/records plus the obs artifacts."""
    from repro.obs import MetricsRegistry, Tracer
    from repro.core.aggregation import SanitizerConfig
    from repro.core.controller import FedLuckController
    from repro.ft import (BandwidthDrift, FailureSchedule, LossyChannel,
                          StragglerDrift)
    kwargs = {"failure_schedule": FailureSchedule.random(
        4, 12.0, rate_per_device=1.0, mean_downtime=0.6, seed=4)}
    if channel:
        kwargs["channel"] = LossyChannel(
            loss_prob=0.3, corrupt_prob=0.1,
            drift=[BandwidthDrift(1, 2.0, 3.0)], seed=7)
        kwargs["sanitizer"] = SanitizerConfig(tau_max=8)
    if controller:
        kwargs["controller"] = FedLuckController(1.0, (1, 8), (0.05, 1.0))
        kwargs["stragglers"] = [StragglerDrift(2, 3.0, 4.0)]
    tracer = Tracer() if obs else None
    metrics = MetricsRegistry() if obs else None
    sim = AFLSimulator(task, _mixed_fleet(), "periodic", round_period=1.0,
                       seed=3, engine=engine, tracer=tracer, metrics=metrics,
                       **kwargs)
    h = sim.run(total_rounds=rounds, eval_every=2)
    out = {
        "w": np.asarray(sim.model.w).copy(),
        "records": [(r.time, r.round, r.accuracy, r.loss, r.gbits,
                     r.mean_staleness, r.drops) for r in h.records],
        "windows": [r.window for r in h.records],
        "counters": dict(h.counters),
        "tracer": tracer,
        "metrics": metrics,
    }
    sim.close()
    return out


class TestObsEquivalence:
    """Observability correctness gate: both engines must emit IDENTICAL
    event sequences and engine-agnostic metrics on identical fault-injected
    runs — and attaching obs must not perturb the simulation at all."""

    def test_identical_event_sequences(self, task):
        b = _obs_fault_run(task, "batched")
        s = _obs_fault_run(task, "sequential")
        assert b["tracer"].events == s["tracer"].events
        assert len(b["tracer"]) > 0
        names = {e.name for e in b["tracer"].events}
        # the fault machinery actually showed up in the trace
        assert {"local_round", "upload", "eval", "arrival",
                "aggregate"} <= names
        assert "crash_lost" in names          # crash markers
        assert "upload_retry" in names        # channel retry spans
        assert "replan" in names              # controller re-plans

    def test_identical_engine_agnostic_metrics(self, task):
        b = _obs_fault_run(task, "batched")
        s = _obs_fault_run(task, "sequential")
        assert (b["metrics"].snapshot(engine_agnostic=True)
                == s["metrics"].snapshot(engine_agnostic=True))
        # engine internals exist only on the batched side
        eng = b["metrics"].snapshot()
        assert eng["histograms"]["engine.drain_size"]["count"] > 0

    def test_faults_metrics_match_history_counters(self, task):
        for eng in ("batched", "sequential"):
            out = _obs_fault_run(task, eng)
            counters = out["metrics"].snapshot()["counters"]
            for k, v in out["counters"].items():
                assert counters[f"faults.{k}"] == float(v), (eng, k)

    def test_obs_attachment_leaves_run_bitwise_unchanged(self, task):
        with_obs = _obs_fault_run(task, "batched", obs=True)
        without = _obs_fault_run(task, "batched", obs=False)
        assert np.array_equal(with_obs["w"], without["w"])
        assert with_obs["records"] == without["records"]
        assert with_obs["counters"] == without["counters"]

    def test_record_windows_attribute_faults_per_eval(self, task):
        out = _obs_fault_run(task, "batched")
        windows = out["windows"]
        # window deltas over non-monotonic-free counters sum back to the
        # cumulative totals (every key of the final counter block)
        for key, total in out["counters"].items():
            assert sum(w.get(key, 0) for w in windows) == total, key
        assert any("staleness_counts" in w for w in windows)


class TestSatellites:
    def test_qsgd_rate_derived_from_levels(self):
        p = DeviceProfile(0, 0.01, 1.0)
        plan = Plan(2, 1.0, 0.0, 1.0, 1)
        spec16 = DeviceSpec(p, plan, "qsgd",
                            compressor_kwargs={"levels": 16})
        spec256 = DeviceSpec(p, plan, "qsgd")
        assert spec16.rate == pytest.approx(5.0 / 32.0)   # log2(16)+1 bits
        assert spec256.rate == pytest.approx(9.0 / 32.0)  # log2(256)+1 bits

    def test_staleness_windows_per_eval(self, task):
        """mean_staleness must reflect only arrivals since the last eval,
        not a fixed last-N slice of the global log."""
        sim = AFLSimulator(task, _mixed_fleet(), "periodic",
                           round_period=1.0, seed=0, engine="batched")
        h = sim.run(total_rounds=6, eval_every=1)
        n_logged = len(sim.agg.staleness_log)
        assert sim._stal_ptr == n_logged     # watermark consumed everything
        assert all(r.mean_staleness >= 0 for r in h.records)
        sim.close()

    def test_k_grid_snaps_plans(self):
        profiles = make_heterogeneous_devices(8, 3.2e6, seed=0)
        grid = [1, 2, 4, 8, 16, 30]
        specs = plan_devices(profiles, "fedluck", 1.0, k_bounds=(1, 30),
                             k_grid=grid)
        assert all(s.plan.k in grid for s in specs)
        # re-solved δ stays inside bounds
        assert all(1e-3 <= s.plan.delta <= 1.0 for s in specs)
