"""End-to-end behaviour tests for the paper's system: FedLuck's claims hold
qualitatively on the simulator (joint adaptation beats fixed settings and
single-factor optimization), and the full train driver restarts cleanly."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import compression as C
from repro.core.simulator import (AFLSimulator, STRATEGY_FOR_METHOD,
                                  make_heterogeneous_devices, plan_devices)
from repro.models.small import make_task

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(scope="module")
def setup():
    task = make_task("mlp_fmnist", num_samples=2000, test_samples=400,
                     batch_size=32, noise=1.2)
    import jax
    params = task.init_fn(jax.random.PRNGKey(0))
    flat, _ = C.flatten_pytree(params)
    profiles = make_heterogeneous_devices(5, flat.size * 32,
                                          base_alpha=0.02, seed=0)
    return task, profiles


def _run(task, profiles, method, rounds=40, **kw):
    specs = plan_devices(profiles, method, 1.0, k_bounds=(1, 20),
                         fixed_k=5, fixed_delta=0.1, **kw)
    skw = {"strategy_kwargs": {"buffer_size": 3}} if method == "fedbuff" \
        else {}
    sim = AFLSimulator(task, specs, STRATEGY_FOR_METHOD[method],
                       round_period=1.0, eta_l=0.05, seed=0, **skw)
    return sim.run(total_rounds=rounds, eval_every=2)


class TestPaperClaims:
    def test_fedluck_competitive_time_to_accuracy(self, setup):
        """Fig. 2: FedLuck reaches the target no slower than FedPer and
        FedAvg+TopK (relative claim, synthetic stand-in data)."""
        task, profiles = setup
        target = 0.75
        t_luck = _run(task, profiles, "fedluck").time_to_accuracy(target)
        t_per = _run(task, profiles, "fedper").time_to_accuracy(target)
        t_avg = _run(task, profiles, "fedavg_topk").time_to_accuracy(target)
        assert t_luck is not None
        assert t_per is None or t_luck <= t_per * 1.05
        assert t_avg is None or t_luck <= t_avg * 1.05

    def test_fedluck_beats_uncompressed_baselines_on_comm(self, setup):
        """Fig. 3: communication to target accuracy well below FedBuff /
        FedAsync (which ship full gradients)."""
        task, profiles = setup
        target = 0.75
        b_luck = _run(task, profiles, "fedluck").bits_to_accuracy(target)
        b_buff = _run(task, profiles, "fedbuff").bits_to_accuracy(target)
        b_async = _run(task, profiles, "fedasync").bits_to_accuracy(target)
        assert b_luck is not None
        for other in (b_buff, b_async):
            if other is not None:
                assert b_luck < other * 0.6   # ≥40% comm saving

    def test_joint_beats_single_factor(self, setup):
        """Tab. 2: joint (k, δ) optimization ≥ Opt.CR / Opt.LF on final
        accuracy at a fixed simulated-time budget."""
        task, profiles = setup
        rounds = 20
        acc_joint = _run(task, profiles, "fedluck", rounds).final_accuracy()
        acc_cr = _run(task, profiles, "opt_cr", rounds).final_accuracy()
        acc_lf = _run(task, profiles, "opt_lf", rounds).final_accuracy()
        assert acc_joint >= acc_cr - 0.03
        assert acc_joint >= acc_lf - 0.03

    def test_noniid_still_converges(self, setup):
        """Tab. 1 setting: Dirichlet(1.0) partitions."""
        from repro.data.partition import dirichlet_partition
        task, profiles = setup
        idx = dirichlet_partition(task.dataset.labels, len(profiles),
                                  alpha=1.0, seed=0)
        specs = plan_devices(profiles, "fedluck", 1.0, k_bounds=(1, 20))
        sim = AFLSimulator(task, specs, "periodic", round_period=1.0,
                           eta_l=0.05, seed=0, client_indices=idx)
        h = sim.run(total_rounds=25, eval_every=5)
        assert h.final_accuracy() > 0.75


class TestDrivers:
    def test_train_cli_checkpoint_restart(self, tmp_path):
        """Kill-and-resume: second invocation continues from the saved
        round instead of restarting from 0."""
        ck = str(tmp_path / "ck")
        env = dict(os.environ, PYTHONPATH=SRC)
        base = [sys.executable, "-m", "repro.launch.train", "--task",
                "mlp_fmnist", "--method", "fedluck", "--devices", "3",
                "--samples", "1200", "--test-samples", "200",
                "--ckpt-dir", ck, "--ckpt-every", "4", "--eval-every", "2",
                "--k-max", "8"]
        r1 = subprocess.run(base + ["--rounds", "8"], capture_output=True,
                            text=True, env=env, timeout=600)
        assert r1.returncode == 0, r1.stderr[-2000:]
        r2 = subprocess.run(base + ["--rounds", "12", "--resume"],
                            capture_output=True, text=True, env=env,
                            timeout=600)
        assert r2.returncode == 0, r2.stderr[-2000:]
        # status lines go to stderr (repro.obs.log) so stdout stays JSON
        assert "resumed from round" in r2.stderr
        assert json.loads(r2.stdout)["rounds"] == 12

    def test_serve_cli(self):
        env = dict(os.environ, PYTHONPATH=SRC)
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.serve", "--arch",
             "mamba2-780m", "--requests", "2", "--batch", "2",
             "--prompt-len", "8", "--gen", "4"],
            capture_output=True, text=True, env=env, timeout=600)
        assert r.returncode == 0, r.stderr[-2000:]
        assert "tokens_per_s" in r.stdout
