"""Per-kernel shape/dtype sweeps, assert_allclose against the ref.py oracles
(kernels run in interpret mode on CPU; same code compiles to Mosaic on TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.ef_topk import ef_topk
from repro.kernels.fused_momentum import fused_momentum
from repro.kernels.magnitude_hist import magnitude_hist

SHAPES = [127, 1024, 8192, 40_000]
DTYPES = [jnp.float32, jnp.bfloat16]


def _g(d, seed=0, dtype=jnp.float32):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randn(d).astype(np.float32)
                       * np.exp(rng.randn(d))).astype(dtype)


class TestMagnitudeHist:
    @pytest.mark.parametrize("d", SHAPES)
    @pytest.mark.parametrize("dtype", DTYPES)
    def test_vs_oracle(self, d, dtype):
        g = _g(d, d, dtype)
        gmax = jnp.max(jnp.abs(g.astype(jnp.float32))) + 1e-30
        edges = gmax * 2.0 ** (-jnp.arange(33, dtype=jnp.float32))
        got = magnitude_hist(g, edges, block=2048, interpret=True)
        want = ref.ref_magnitude_hist(g, edges)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_padding_does_not_count(self):
        g = _g(100, 1)   # padded to one 2048 block internally
        edges = jnp.asarray([1e-20], jnp.float32)  # everything >= this
        got = magnitude_hist(g, edges, block=2048, interpret=True)
        assert float(got[0]) == 100.0  # zeros from padding excluded


class TestEfTopk:
    @pytest.mark.parametrize("d", SHAPES)
    @pytest.mark.parametrize("dtype", DTYPES)
    def test_vs_oracle(self, d, dtype):
        g, r = _g(d, d, dtype), _g(d, d + 1, dtype) * 0.1
        t = jnp.float32(0.5)
        out_k, res_k, nnz_k = ef_topk(g, r, t, block=2048, interpret=True)
        out_r, res_r, nnz_r = ref.ref_ef_topk(g, r, t)
        np.testing.assert_allclose(np.asarray(out_k, np.float32),
                                   np.asarray(out_r, np.float32),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(res_k, np.float32),
                                   np.asarray(res_r, np.float32),
                                   rtol=1e-5, atol=1e-6)
        assert float(nnz_k) == float(nnz_r)

    def test_conservation(self):
        """out + residual' == g + residual exactly (fp32)."""
        g, r = _g(5000, 2), _g(5000, 3) * 0.2
        out, res, _ = ef_topk(g, r, jnp.float32(1.0), interpret=True)
        np.testing.assert_allclose(np.asarray(out + res),
                                   np.asarray(g + r), rtol=1e-6)


class TestTopkCompressPipeline:
    @pytest.mark.parametrize("rate", [0.001, 0.01, 0.1])
    @pytest.mark.parametrize("d", [10_000, 100_000])
    def test_density_and_selection(self, rate, d):
        g = _g(d, d)
        res = jnp.zeros(d)
        out, new_res, nnz, t = ops.topk_compress(g, res, rate=rate,
                                                 interpret=True)
        k = max(1, round(rate * d))
        assert float(nnz) <= k + 1
        assert float(nnz) >= 0.9 * k - 1
        # EF decomposition holds for the full pipeline too
        np.testing.assert_allclose(np.asarray(out + new_res),
                                   np.asarray(g), rtol=1e-5, atol=1e-6)
        # every kept value beats every dropped value in magnitude (threshold)
        o = np.asarray(out)
        kept = np.abs(o[o != 0])
        dropped = np.abs(np.asarray(g))[o == 0]
        if len(kept) and len(dropped):
            assert kept.min() >= dropped.max() - 1e-5 or \
                kept.min() >= float(t) - 1e-7

    @pytest.mark.parametrize("nnz", [0, 7, 64])
    def test_compact_topk_round_trip(self, nnz):
        """scatter(values, indices) reconstructs the dense masked vector
        exactly whenever the capacity covers the support."""
        d, cap = 5000, 64
        rng = np.random.RandomState(nnz)
        dense = np.zeros(d, np.float32)
        support = rng.choice(d, size=nnz, replace=False)
        dense[support] = rng.randn(nnz).astype(np.float32)
        vals, idx = ops.compact_topk(jnp.asarray(dense), cap)
        assert vals.shape == idx.shape == (cap,)
        rebuilt = np.zeros(d, np.float32)
        np.add.at(rebuilt, np.asarray(idx), np.asarray(vals))
        np.testing.assert_array_equal(rebuilt, dense)

    def test_compact_topk_sparse_pipeline_round_trip(self):
        """topk_compress_sparse wire pair rebuilds the dense pipeline
        output bit-for-bit at the tested rate."""
        d = 40_000
        g, res = _g(d, 21), _g(d, 22) * 0.1
        dense, _, _, _ = ops.topk_compress(g, res, rate=0.01, interpret=True)
        vals, idx, _, nnz, _ = ops.topk_compress_sparse(g, res, rate=0.01,
                                                        interpret=True)
        assert float(nnz) <= vals.shape[0]
        rebuilt = np.zeros(d, np.float32)
        np.add.at(rebuilt, np.asarray(idx), np.asarray(vals))
        np.testing.assert_array_equal(rebuilt, np.asarray(dense))

    def test_statistics_use_ef_accumulator(self):
        """Threshold must be computed on g+residual, not g alone."""
        d = 10_000
        g = jnp.zeros(d)
        res = _g(d, 11)  # all signal lives in the residual
        out, _, nnz, _ = ops.topk_compress(g, res, rate=0.01, interpret=True)
        assert float(nnz) > 0


class TestCompactBlocks:
    """compact_topk.compact_blocks — the pod-sync wire-format kernel."""

    def _acc(self, nb, blk, seed=0):
        rng = np.random.RandomState(seed)
        return jnp.asarray(rng.randn(nb, blk).astype(np.float32)
                           * np.exp(rng.randn(nb, blk).astype(np.float32)))

    @pytest.mark.parametrize("nb,blk", [(1, 128), (8, 64), (12, 256)])
    @pytest.mark.parametrize("budget", [1, 5, 32])
    def test_vs_oracle_bitwise(self, nb, blk, budget):
        from repro.kernels.compact_topk import compact_blocks
        acc = self._acc(nb, blk, nb * blk + budget)
        t = jnp.float32(np.median(np.abs(np.asarray(acc))) * 2)
        got = compact_blocks(acc, t, budget=budget, interpret=True)
        want = ref.ref_compact_blocks(acc, t, budget)
        for g_, w_, name in zip(got, want, ("vals", "idx", "cnt", "res")):
            np.testing.assert_array_equal(np.asarray(g_), np.asarray(w_),
                                          err_msg=name)

    @pytest.mark.parametrize("threshold,expect", [(0.0, "all"),
                                                  (np.inf, "none")])
    def test_degenerate_thresholds(self, threshold, expect):
        from repro.kernels.compact_topk import compact_blocks
        nb, blk, budget = 4, 64, 8
        acc = self._acc(nb, blk, 3)
        vals, idx, cnt, res = compact_blocks(acc, jnp.float32(threshold),
                                             budget=budget, interpret=True)
        if expect == "none":   # t=inf: nothing ships, residual == acc
            assert (np.asarray(cnt) == 0).all()
            np.testing.assert_array_equal(np.asarray(res), np.asarray(acc))
            assert not np.asarray(vals).any() and not np.asarray(idx).any()
        else:                  # t=0: every block overflows to exactly budget
            assert (np.asarray(cnt) == budget).all()
            # kept entries are the FIRST `budget` coords of each block
            # (front-packed in index order), rest defer via residual
            np.testing.assert_array_equal(
                np.asarray(vals), np.asarray(acc)[:, :budget])

    def test_scatter_reconstructs_shipped_selection(self):
        """zeros.at[idx].add(vals) == acc − residual (padding slots are
        (0.0, 0) no-ops) — the property the compact pod-sync relies on."""
        from repro.kernels.compact_topk import compact_blocks
        nb, blk, budget = 8, 128, 6
        acc = self._acc(nb, blk, 17)
        t = jnp.float32(np.quantile(np.abs(np.asarray(acc)), 0.95))
        vals, idx, cnt, res = compact_blocks(acc, t, budget=budget,
                                             interpret=True)
        rebuilt = np.zeros(nb * blk, np.float32)
        np.add.at(rebuilt, np.asarray(idx).ravel(), np.asarray(vals).ravel())
        np.testing.assert_array_equal(rebuilt.reshape(nb, blk),
                                      np.asarray(acc - res))
        # indices are shard-flat (block i owns [i·blk, (i+1)·blk))
        live = np.arange(budget)[None, :] < np.asarray(cnt)[:, None]
        blocks = np.asarray(idx) // blk
        assert (blocks[live] == np.nonzero(live)[0]).all()

    def test_shard_pipeline_matches_threshold_solve(self):
        """compact_shard_topk == solve_threshold + compact_blocks, and the
        shard threshold equals topk_compress's on the same flat vector."""
        nb, blk, rate = 8, 256, 0.0625   # rate·blk integral, so the shard
        budget = max(1, min(blk, round(rate * blk)))   # target nb·budget
        assert nb * budget == round(rate * nb * blk)   # == pipeline k
        acc = self._acc(nb, blk, 29)
        vals, idx, cnt, res = ops.compact_shard_topk(acc, budget=budget,
                                                     interpret=True)
        t = ops.solve_threshold(acc.reshape(-1), nb * budget, interpret=True)
        want = ref.ref_compact_blocks(acc, t, budget)
        for g_, w_, name in zip((vals, idx, cnt, res), want,
                                ("vals", "idx", "cnt", "res")):
            np.testing.assert_array_equal(np.asarray(g_), np.asarray(w_),
                                          err_msg=name)
        # solve_threshold is the extracted topk_compress solver: same t
        _, _, _, t_pipe = ops.topk_compress(
            acc.reshape(-1), jnp.zeros(nb * blk), rate=rate, interpret=True)
        assert float(t) == float(t_pipe)


class TestFusedMomentum:
    @pytest.mark.parametrize("d", SHAPES)
    @pytest.mark.parametrize("dtype", DTYPES)
    def test_vs_oracle(self, d, dtype):
        w, mu, g = _g(d, 5, dtype), _g(d, 6), _g(d, 7, dtype)
        w2, mu2 = fused_momentum(w, mu, g, lr=0.1, momentum=0.9,
                                 block=2048, interpret=True)
        rw, rmu = ref.ref_fused_momentum(w, mu, g, lr=0.1, momentum=0.9)
        np.testing.assert_allclose(np.asarray(w2, np.float32),
                                   np.asarray(rw, np.float32),
                                   rtol=2e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(mu2, np.float32),
                                   np.asarray(rmu, np.float32),
                                   rtol=2e-5, atol=1e-6)

    def test_matches_optimizer_semantics(self):
        """Kernel == repro.optim.momentum_sgd on a flat vector."""
        from repro.optim import momentum_sgd
        d = 2000
        w, g = _g(d, 8), _g(d, 9)
        opt = momentum_sgd(0.05, momentum=0.9)
        st = opt.init(w)
        w_ref, _ = opt.update(g, st, w)
        w_k, _ = fused_momentum(w, jnp.zeros(d), g, lr=0.05, momentum=0.9,
                                interpret=True)
        np.testing.assert_allclose(np.asarray(w_k), np.asarray(w_ref),
                                   rtol=2e-5, atol=1e-6)
