"""Distribution tests on 8 placeholder devices.

These run in a SUBPROCESS with XLA_FLAGS set so the main pytest process
keeps its single CPU device (per the dry-run spec)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(body: str):
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from jax.sharding import PartitionSpec as P, NamedSharding
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-4000:]}"
    return r.stdout


class TestShardingRules:
    def test_param_specs_follow_rules_and_divisibility(self):
        _run("""
        from repro.configs import get_config
        from repro.dist import sharding as shl
        from repro.models.transformer import LM
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        cfg = get_config("gemma3-4b").smoke()
        lm = LM(cfg, dtype=jnp.float32, remat=False)
        shapes = jax.eval_shape(lm.init, jax.random.key(0))
        specs = shl.param_specs(shapes, mesh)
        # embedding [V, d]: vocab on model, d on data
        assert specs["embed"]["embedding"] == P("model", "data"), specs["embed"]
        # wq [L, d, H*hd]: fsdp in, tp out
        assert specs["layers"]["wq"]["kernel"] == P(None, "data", "model")
        # wo transpose layout
        assert specs["layers"]["wo"]["kernel"] == P(None, "model", "data")
        # every spec divides its dim
        flat_s = jax.tree_util.tree_leaves_with_path(shapes)
        flat_p = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        for (path, sh), spec in zip(flat_s, flat_p):
            for dim, ax in enumerate(spec):
                if ax is not None:
                    assert sh.shape[dim] % mesh.shape[ax] == 0, (path, spec)
        print("OK")
        """)

    def test_moe_tp_in_expert_layout(self):
        _run("""
        from repro.configs import get_config
        from repro.dist import sharding as shl
        from repro.models.transformer import LM
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        cfg = get_config("qwen3-moe-30b-a3b").smoke()
        lm = LM(cfg, dtype=jnp.float32, remat=False)
        shapes = jax.eval_shape(lm.init, jax.random.key(0))
        specs = shl.param_specs(shapes, mesh)
        # TP-in-expert: [L, E, d(fsdp), f(model)] / w_down [L, E, f(model), d]
        assert specs["layers"]["moe"]["w_gate"] == P(None, None, "data",
                                                     "model")
        assert specs["layers"]["moe"]["w_down"] == P(None, None, "model",
                                                     "data")
        # router replicated (the sharded dispatch broadcasts it)
        assert all(e is None
                   for e in specs["layers"]["moe"]["router"]["kernel"])
        print("OK")
        """)


class TestShardedTraining:
    def test_sharded_train_step_matches_single_device(self):
        """The pjit'd train step on a 2×4 mesh computes THE SAME numbers as
        the unsharded step (GSPMD is semantics-preserving)."""
        _run("""
        from repro.configs import get_config
        from repro.dist import sharding as shl
        from repro.dist.steps import make_train_step
        from repro.models.transformer import LM
        from repro.optim import momentum_sgd

        cfg = dataclasses.replace(get_config("stablelm-3b").smoke(),
                                  vocab=256, n_layers=2)
        lm = LM(cfg, dtype=jnp.float32, remat=True, batch_axes=("data",))
        opt = momentum_sgd(0.01)
        params = lm.init(jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        rng = np.random.RandomState(0)
        batch = {"tokens": jnp.asarray(rng.randint(0, 256, (8, 64)),
                                       jnp.int32),
                 "labels": jnp.asarray(rng.randint(0, 256, (8, 64)),
                                       jnp.int32)}
        step = make_train_step(lm, opt)
        # single device reference
        lm_ref = LM(cfg, dtype=jnp.float32, remat=True)
        _, _, loss_ref = jax.jit(make_train_step(lm_ref, opt))(
            params, opt_state, batch)

        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        pspec = shl.param_specs(params, mesh)
        ospec = shl.opt_state_specs(jax.eval_shape(lambda: opt_state),
                                    pspec, mesh)
        bspec = shl.batch_specs(batch, mesh, batch_axes=("data",))
        ns = lambda t: shl.named(t, mesh)
        with mesh:
            new_p, _, loss = jax.jit(
                step, in_shardings=(ns(pspec), ns(ospec), ns(bspec)),
                out_shardings=(ns(pspec), ns(ospec),
                               NamedSharding(mesh, P())))(
                params, opt_state, batch)
        assert np.isfinite(float(loss))
        np.testing.assert_allclose(float(loss), float(loss_ref), rtol=2e-4)
        print("OK", float(loss), float(loss_ref))
        """)

    def test_pod_sync_collective(self):
        """FedLuck Eq. 6 over a (pod, data, model) mesh: sync_step averages
        compressed deltas across pods exactly (δ-adaptive path)."""
        _run("""
        from repro.dist.collectives import block_budget, make_pod_sync
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*3)
        nb, blk = 8, 64
        dim = nb * blk
        rng = np.random.RandomState(0)
        params = jnp.zeros((nb, blk), jnp.float32)
        deltas = jnp.asarray(rng.randn(2, nb, blk).astype(np.float32))
        residuals = jnp.zeros((2, nb, blk), jnp.float32)
        for rate in (0.6, 0.05):        # dense ring, then compact gather
            sync = make_pod_sync(mesh, dim, rate=rate, eta_g=1.0,
                                 n_blocks=nb)
            with mesh:
                new_p, new_r = jax.jit(sync)(params, deltas, residuals)
            # EF conservation per pod: kept + residual' == delta
            kept = np.asarray(deltas) - np.asarray(new_r)
            # Eq. 6: params' = -mean(kept) over pods
            np.testing.assert_allclose(np.asarray(new_p),
                                       -(kept[0] + kept[1]) / 2,
                                       rtol=1e-4, atol=1e-5)
            nnz = (np.abs(kept) > 0).sum(axis=(1, 2))
            k = round(rate * dim)
            if rate >= 0.5:
                # dense path: exact global threshold → density ≈ rate and
                # kept values are the largest magnitudes
                assert sync.path == "dense"
                assert (nnz <= 1.25 * k + nb).all() and \
                       (nnz >= 0.75 * k - 1).all(), (nnz, k)
                for i in range(2):
                    kmags = np.abs(kept[i])[np.abs(kept[i]) > 0]
                    dmags = np.abs(np.asarray(deltas[i]))[
                        np.abs(kept[i]) == 0]
                    assert kmags.min() >= dmags.max() - 0.05
            else:
                # compact path: per-shard threshold + fixed per-block
                # budget. Capacity-bounded (over-budget entries defer to
                # the next round via EF) and never emptier than half the
                # target; each block respects its slot budget; everything
                # shipped sits far above the bulk of the magnitudes.
                assert sync.path == "compact"
                budget = block_budget(blk, rate)
                assert budget == sync.wire.budget
                assert (nnz <= nb * budget).all() and \
                       (nnz >= 0.5 * k).all(), (nnz, k, nb * budget)
                per_block = (np.abs(kept) > 0).sum(axis=2)
                assert (per_block <= budget).all()
                for i in range(2):
                    kmags = np.abs(kept[i])[np.abs(kept[i]) > 0]
                    assert kmags.min() >= \
                        np.median(np.abs(np.asarray(deltas[i])))
        print("OK")
        """)

    def test_pod_sync_compact_matches_reference_across_crossover(self):
        """Compact (values, indices, count) gather vs the dense-carrier
        reference of the same selection semantics: identical params (fp32)
        and bitwise-identical EF residuals, carried over 3 rounds, for δ on
        both sides of density_crossover."""
        _run("""
        from repro.dist import collectives as col
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*3)
        nb, blk = 8, 64
        dim = nb * blk
        crossover = col.density_crossover(2)
        rng = np.random.RandomState(1)
        params = jnp.asarray(rng.randn(nb, blk).astype(np.float32))
        zeros = jnp.zeros((2, nb, blk), jnp.float32)
        for rate in (0.05, 0.6):
            assert (rate < crossover) == (rate == 0.05)
            jc = jax.jit(col.make_pod_sync(mesh, dim, rate=rate,
                                           n_blocks=nb, wire="compact"))
            jr = jax.jit(col.make_pod_sync(mesh, dim, rate=rate,
                                           n_blocks=nb, wire="reference"))
            pc, rc = params, zeros
            pr, rr = params, zeros
            for rnd in range(3):
                d = jnp.asarray(rng.randn(2, nb, blk).astype(np.float32))
                with mesh:
                    pc, rc = jc(pc, d, rc)
                    pr, rr = jr(pr, d, rr)
                assert np.allclose(np.asarray(pc), np.asarray(pr),
                                   rtol=1e-5, atol=1e-6), (rate, rnd)
                assert np.array_equal(np.asarray(rc), np.asarray(rr)), \\
                    (rate, rnd)
            # residuals actually carry: round-2 EF state is nonzero
            assert float(np.abs(np.asarray(rc)).max()) > 0
        # wire-cost model matches the payload the compact sync ships
        sync = col.make_pod_sync(mesh, dim, rate=0.05, n_blocks=nb,
                                 wire="compact")
        per_shard = sync.wire
        assert sync.bytes_per_device == \\
            col.all_gather_bytes(per_shard.dim, 2, 0.05,
                                 n_blocks=per_shard.n_blocks)
        print("OK")
        """)

    def test_pod_round_step_composes_local_rounds_and_sync(self):
        """make_pod_round_step == (vmapped local rounds) ∘ make_pod_sync,
        and its static wire-bit charge is the sync's compact payload."""
        _run("""
        from repro.configs import get_config
        from repro.core import compression as C
        from repro.dist import collectives as col
        from repro.dist.steps import make_local_round_step, \\
            make_pod_round_step
        from repro.models.transformer import LM
        from repro.optim import momentum_sgd

        cfg = dataclasses.replace(get_config("stablelm-3b").smoke(),
                                  vocab=256, n_layers=1)
        lm = LM(cfg, dtype=jnp.float32, remat=False)
        opt = momentum_sgd(0.01)
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*3)
        params = lm.init(jax.random.PRNGKey(0))
        flat, spec = C.flatten_pytree(params)
        dim = int(flat.shape[0])
        blk = 256
        nb = -(-dim // blk)
        while nb % 4:       # shard nb over the 4 in-pod chips
            nb += 1
        dim_pad = nb * blk
        rng = np.random.RandomState(0)
        k, B, P_pods = 2, 4, 2
        batches = {
          "tokens": jnp.asarray(rng.randint(0, 256, (P_pods, k, B, 32)),
                                jnp.int32),
          "labels": jnp.asarray(rng.randint(0, 256, (P_pods, k, B, 32)),
                                jnp.int32)}
        pb = jnp.concatenate([flat, jnp.zeros((dim_pad - dim,),
                                              jnp.float32)]).reshape(nb, blk)
        residuals = jnp.zeros((P_pods, nb, blk), jnp.float32)
        opt_states = jax.tree.map(
            lambda x: jnp.stack([x] * P_pods), opt.init(params))

        sync = col.make_pod_sync(mesh, dim_pad, rate=0.05, n_blocks=nb)
        step = make_pod_round_step(lm, opt, k, sync, spec=spec, dim=dim,
                                   n_blocks=nb)
        assert step.wire_bits_per_pod == 4 * sync.wire.payload_bits()
        with mesh:
            new_pb, new_states, new_res, loss = jax.jit(step)(
                pb, opt_states, batches, residuals)
        assert np.isfinite(float(loss))

        # reference: run the local rounds and the sync separately
        local = make_local_round_step(lm, opt, k)
        deltas = []
        for p in range(P_pods):
            ob = jax.tree.map(lambda x: x[p], opt_states)
            bb = jax.tree.map(lambda x: x[p], batches)
            _, _, delta, _ = jax.jit(local)(params, ob, bb)
            fd, _ = C.flatten_pytree(delta)
            deltas.append(np.pad(np.asarray(fd), (0, dim_pad - dim)))
        deltas = jnp.asarray(np.stack(deltas)).reshape(P_pods, nb, blk)
        with mesh:
            ref_pb, ref_res = jax.jit(sync)(pb, deltas, residuals)
        # the composed program and the split reference compile with
        # different layouts/fusions (GSPMD reduce order), so the deltas
        # themselves carry ~1e-3 float noise — loose tolerance here; the
        # bitwise sync-equivalence guarantees live in the test above
        assert np.allclose(np.asarray(new_pb), np.asarray(ref_pb),
                           rtol=1e-3, atol=2e-3)
        assert np.allclose(np.asarray(new_res), np.asarray(ref_res),
                           rtol=1e-3, atol=2e-3)
        print("OK")
        """)

    def test_decode_step_with_sequence_sharded_cache(self):
        """Flash-decoding: KV cache sequence dim sharded over `model`;
        decode result matches the unsharded reference."""
        _run("""
        from repro.configs import get_config
        from repro.dist import sharding as shl
        from repro.models.transformer import LM

        cfg = dataclasses.replace(get_config("gemma3-4b").smoke(),
                                  vocab=128, n_layers=2)
        lm = LM(cfg, dtype=jnp.float32, remat=False)
        params = lm.init(jax.random.PRNGKey(0))
        B, S = 4, 64
        cache = lm.init_cache(B, S)
        rng = np.random.RandomState(1)
        cache = {k: (jnp.asarray(rng.randn(*v.shape).astype(np.float32))
                     if k in ("k", "v") else v) for k, v in cache.items()}
        tok = jnp.asarray(rng.randint(0, 128, (B, 1)), jnp.int32)
        idx = jnp.int32(40)
        ref_logits, _ = jax.jit(lm.decode_step)(params, cache, tok, idx)

        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        pspec = shl.param_specs(params, mesh)
        cspec = shl.cache_specs(cache, mesh, batch_axes=("data",))
        # assert the cache S dim really is sharded
        assert cspec["k"][2] == "model", cspec["k"]
        ns = lambda t: shl.named(t, mesh)
        with mesh:
            logits, _ = jax.jit(
                lm.decode_step,
                in_shardings=(ns(pspec), ns(cspec),
                              NamedSharding(mesh, P("data")),
                              NamedSharding(mesh, P())),
                out_shardings=(NamedSharding(mesh, P()), ns(cspec)))(
                params, cache, tok, idx)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(ref_logits), rtol=2e-4,
                                   atol=2e-4)
        print("OK")
        """)


class TestMicrobatching:
    def test_grad_accumulation_matches_full_batch(self):
        """make_train_step(microbatches=n) computes the same update as the
        full-batch step (fault-free math under activation-memory savings)."""
        _run("""
        from repro.configs import get_config
        from repro.dist.steps import make_train_step
        from repro.models.transformer import LM
        from repro.optim import momentum_sgd

        cfg = dataclasses.replace(get_config("stablelm-3b").smoke(),
                                  vocab=128, n_layers=2)
        lm = LM(cfg, dtype=jnp.float32, remat=True)
        opt = momentum_sgd(0.01)
        params = lm.init(jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        rng = np.random.RandomState(0)
        batch = {"tokens": jnp.asarray(rng.randint(0, 128, (8, 32)),
                                       jnp.int32),
                 "labels": jnp.asarray(rng.randint(0, 128, (8, 32)),
                                       jnp.int32)}
        full = jax.jit(make_train_step(lm, opt))
        accum = jax.jit(make_train_step(lm, opt, microbatches=4))
        p1, _, l1 = full(params, opt_state, batch)
        p2, _, l2 = accum(params, opt_state, batch)
        np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-6)
        print("OK")
        """)
