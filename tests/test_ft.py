"""Fault tolerance: failures, stragglers, lossy channels, sanitization,
elastic membership, restart."""
import numpy as np
import pytest

from repro.core.simulator import (AFLSimulator, DeviceSpec, plan_devices,
                                  make_heterogeneous_devices)
from repro.ft import (BandwidthDrift, FailureSchedule, FailureWindow,
                      LossyChannel, RetryPolicy, StragglerDrift,
                      merge_overlaps)
from repro.models.small import make_task


@pytest.fixture(scope="module")
def task():
    return make_task("mlp_fmnist", num_samples=1000, test_samples=300,
                     batch_size=32)


class TestFailureSchedule:
    def test_is_down_semantics(self):
        fs = FailureSchedule([FailureWindow(0, 2.0, 5.0)])
        assert not fs.is_down(0, 1.9)
        assert fs.is_down(0, 2.0)
        assert fs.is_down(0, 4.99)
        assert not fs.is_down(0, 5.0)
        assert not fs.is_down(1, 3.0)

    def test_lost_in_flight(self):
        fs = FailureSchedule([FailureWindow(0, 2.0, 5.0)])
        assert fs.lost_in_flight(0, 1.0, 3.0)      # crash mid-upload
        assert not fs.lost_in_flight(0, 2.5, 4.0)  # started while down
        assert not fs.lost_in_flight(0, 5.5, 6.0)  # after recovery

    def test_recovery_time_chains_windows(self):
        fs = FailureSchedule([FailureWindow(0, 2.0, 5.0),
                              FailureWindow(0, 5.0, 7.0)])
        assert fs.recovery_time(0, 3.0) == 7.0

    def test_random_generator(self):
        fs = FailureSchedule.random(5, horizon=100.0, rate_per_device=1.0,
                                    seed=0)
        assert all(w.end > w.start for w in fs.windows)

    def test_merge_overlaps_coalesces(self):
        merged = merge_overlaps([FailureWindow(0, 4.0, 6.0),
                                 FailureWindow(0, 1.0, 3.0),
                                 FailureWindow(0, 2.0, 4.0),   # touches both
                                 FailureWindow(1, 0.0, 1.0)])
        assert merged == [FailureWindow(0, 1.0, 6.0),
                          FailureWindow(1, 0.0, 1.0)]

    def test_merge_overlaps_validates(self):
        with pytest.raises(ValueError):
            merge_overlaps([FailureWindow(0, 5.0, 5.0)])
        with pytest.raises(ValueError):
            FailureSchedule([FailureWindow(0, 5.0, 2.0)])

    def test_merged_schedule_copy(self):
        fs = FailureSchedule([FailureWindow(0, 2.0, 5.0),
                              FailureWindow(0, 4.0, 7.0)])
        assert fs.merge_overlaps().windows == [FailureWindow(0, 2.0, 7.0)]

    def test_indexed_matches_naive_scan(self):
        """O(log W) indexed queries agree with a brute-force window scan."""
        fs = FailureSchedule.random(4, horizon=50.0, rate_per_device=3.0,
                                    seed=7)
        rng = np.random.RandomState(0)
        for _ in range(300):
            d = int(rng.randint(0, 5))          # incl. a device w/o windows
            t = float(rng.uniform(-1.0, 55.0))
            naive = any(w.device_id == d and w.start <= t < w.end
                        for w in fs.windows)
            assert fs.is_down(d, t) == naive
        merged = merge_overlaps(fs.windows)
        for _ in range(300):
            d = int(rng.randint(0, 5))
            s = float(rng.uniform(0.0, 50.0))
            f = s + float(rng.uniform(0.0, 5.0))
            naive = any(w.device_id == d and s < w.start < f for w in merged)
            assert fs.lost_in_flight(d, s, f) == naive

    def test_crash_recovery(self):
        fs = FailureSchedule([FailureWindow(0, 2.0, 5.0)])
        # outage opens at 2.0 inside the flight (1.0, 3.0) -> back up at 5.0
        assert fs.crash_recovery(0, 1.0, 3.0) == 5.0
        assert fs.crash_recovery(0, 2.5, 4.0) is None   # started while down
        assert fs.crash_recovery(0, 5.5, 9.0) is None
        assert fs.crash_recovery(1, 1.0, 3.0) is None


class TestLossyChannel:
    def test_clean_link_timing(self):
        ch = LossyChannel(loss_prob=0.0)
        arrive, attempts, give_up = ch.transmit(0, 10.0, 0.5)
        assert (arrive, attempts, give_up) == (10.5, 1, 10.5)
        assert ch.counters["delivered"] == 1
        assert ch.counters["retries"] == 0

    def test_always_lost_gives_up_with_backoff(self):
        retry = RetryPolicy(max_attempts=3, timeout=0.25, backoff=2.0)
        ch = LossyChannel(loss_prob=1.0, retry=retry)
        arrive, attempts, give_up = ch.transmit(0, 0.0, 1.0)
        assert arrive is None
        assert attempts == 3
        # 3 uploads of 1s + waits 0.25, 0.5 after the two lost non-final...
        # every lost attempt waits: 0.25 + 0.5 + 1.0 after the 3rd
        assert give_up == pytest.approx(3.0 + 0.25 + 0.5 + 1.0)
        assert ch.counters == {"attempts": 3, "retries": 2, "delivered": 0,
                               "channel_dropped": 1, "corrupted": 0,
                               "retx_bits": 0.0, "lost_bits": 0.0}

    def test_charge_wire_retx_and_lost_accounting(self):
        ch = LossyChannel(loss_prob=0.0)
        ch.charge_wire(100.0, attempts=3, delivered=True)   # 2 retransmits
        assert ch.counters["retx_bits"] == 200.0
        assert ch.counters["lost_bits"] == 0.0
        ch.charge_wire(100.0, attempts=2, delivered=False)  # dropped upload
        assert ch.counters["lost_bits"] == 200.0           # every attempt lost
        assert ch.counters["retx_bits"] == 200.0

    def test_per_device_streams_independent_of_interleaving(self):
        """Outcomes for a device depend only on its own draw order — the
        property that keeps batched/sequential engines bitwise equal."""
        a = LossyChannel(loss_prob=0.5, seed=3)
        b = LossyChannel(loss_prob=0.5, seed=3)
        outs_a = [a.transmit(0, t, 1.0) for t in range(4)]
        outs_b = []
        for t in range(4):                      # interleave another device
            b.transmit(7, float(t), 1.0)
            outs_b.append(b.transmit(0, float(t), 1.0))
        assert outs_a == outs_b

    def test_bandwidth_drift_scales_attempts(self):
        ch = LossyChannel(drift=[BandwidthDrift(0, 5.0, 3.0)])
        arrive, _, _ = ch.transmit(0, 1.0, 1.0)
        assert arrive == 2.0                    # before drift: clean β
        arrive, _, _ = ch.transmit(0, 6.0, 1.0)
        assert arrive == 9.0                    # after drift: 3× slower
        assert ch.beta_multiplier(1, 10.0) == 1.0   # other devices untouched

    def test_reset_rearms_streams(self):
        ch = LossyChannel(loss_prob=0.5, corrupt_prob=0.5, seed=1)
        first = [ch.transmit(0, 0.0, 1.0) for _ in range(5)]
        ch.reset()
        again = [ch.transmit(0, 0.0, 1.0) for _ in range(5)]
        assert first == again


class TestSimulatorUnderFailures:
    def test_training_survives_device_crashes(self, task):
        """AFL keeps converging when a device dies mid-run (its updates are
        simply absent from S^t — the core fault-tolerance property)."""
        profs = make_heterogeneous_devices(4, 3.2e6, seed=0)
        specs = plan_devices(profs, "fedluck", 1.0, k_bounds=(1, 8))
        fs = FailureSchedule([FailureWindow(0, 1.0, 6.0),
                              FailureWindow(1, 2.0, 4.0)])
        sim = AFLSimulator(task, specs, "periodic", round_period=1.0,
                           eta_l=0.05, seed=0, failure_schedule=fs)
        h = sim.run(total_rounds=14, eval_every=4)
        assert h.final_accuracy() > 0.7

    def test_failed_device_contributes_nothing_while_down(self, task):
        profs = make_heterogeneous_devices(2, 3.2e6, seed=1)
        specs = plan_devices(profs, "fedper", 1.0, fixed_k=2,
                             fixed_delta=0.5)
        fs = FailureSchedule([FailureWindow(0, 0.0, 1e9)])  # dev 0 always down
        sim = AFLSimulator(task, specs, "periodic", round_period=1.0,
                           seed=0, failure_schedule=fs)
        sim.run(total_rounds=6, eval_every=0)
        # only device 1's uploads were ever aggregated (payload-shape
        # accounting: k values + k indices + kept-count header)
        from repro.core import compression as C
        per_upload = C.num_keep(sim.dim, specs[1].rate) * 64 + C.HEADER_BITS
        assert sim.agg.total_bits % per_upload == 0


class TestSanitizedRun:
    def test_nan_and_lossy_devices_complete_with_finite_loss(self, task):
        """Acceptance: a fleet with a NaN-corrupting link and upload loss
        completes with nonzero sanitization/drop counters surfaced in
        History and a finite final loss."""
        profs = make_heterogeneous_devices(4, 3.2e6, seed=0)
        specs = plan_devices(profs, "fedluck", 1.0, k_bounds=(1, 8))
        ch = LossyChannel(loss_prob={0: 0.5}, corrupt_prob={1: 0.8},
                          retry=RetryPolicy(max_attempts=2), seed=2)
        from repro.core.aggregation import SanitizerConfig
        sim = AFLSimulator(task, specs, "periodic", round_period=1.0,
                           eta_l=0.05, seed=0, channel=ch,
                           sanitizer=SanitizerConfig(tau_max=6))
        h = sim.run(total_rounds=12, eval_every=4)
        assert np.isfinite(h.records[-1].loss)
        assert np.all(np.isfinite(sim.model.w))
        assert h.counters["sanitized_nonfinite"] > 0   # NaNs were caught
        assert h.counters["retries"] > 0
        assert h.counters["drops_total"] > 0
        assert h.records[-1].drops == h.counters["drops_total"]
        sim.close()

    def test_without_sanitizer_nans_poison_model(self, task):
        """The guard is load-bearing: the same corrupting fleet without a
        sanitizer drives the global model non-finite."""
        profs = make_heterogeneous_devices(2, 3.2e6, seed=0)
        specs = plan_devices(profs, "fedper", 1.0, fixed_k=2,
                             fixed_delta=0.5)
        ch = LossyChannel(corrupt_prob=1.0, seed=2)
        sim = AFLSimulator(task, specs, "periodic", round_period=1.0,
                           seed=0, channel=ch)
        sim.run(total_rounds=3, eval_every=0)
        assert not np.all(np.isfinite(sim.model.w))
        sim.close()


class TestDriftReplan:
    def test_straggler_drift_triggers_midrun_replan(self, task):
        """A device slowing down mid-run (α drift past the controller's
        tolerance) gets a fresh, smaller-k plan without restarting."""
        from repro.core.controller import FedLuckController
        profs = make_heterogeneous_devices(3, 3.2e6, seed=0)
        ctl = FedLuckController(1.0, k_bounds=(1, 8))
        specs = plan_devices(profs, "fedluck", 1.0, k_bounds=(1, 8),
                             controller=ctl)
        k_before = {s.profile.device_id: s.plan.k for s in specs}
        sim = AFLSimulator(task, specs, "periodic", round_period=1.0,
                           seed=0, controller=ctl,
                           stragglers=[StragglerDrift(0, 2.0, 6.0)])
        h = sim.run(total_rounds=10, eval_every=0)
        assert ctl.replans > 0
        assert h.counters["replans"] == ctl.replans
        # the straggler runs fewer local steps under its 6× slower α
        assert sim.devices[0].plan.k < k_before[0]
        # devices that did not drift keep their original plans
        assert sim.devices[1].plan.k == k_before[1]
        sim.close()


class TestResumeUnderFailure:
    """Checkpoint resume mid-run with an ACTIVE FailureSchedule must replay
    deterministically: the resumed segment sees the same crash windows as
    the uninterrupted run's same segment (run() restarts the simulated
    clock per segment, exactly like launch/train.py's segment loop)."""

    @staticmethod
    def _sim():
        from repro.core.controller import DeviceProfile
        from repro.core.factor import Plan
        # batch_size >= client subset -> loader-state-free dynamics, so a
        # fresh sim resumed from a checkpoint is comparable (same trick as
        # tests/test_checkpoint.py::TestFLResume)
        task = make_task("mlp_fmnist", num_samples=64, test_samples=32,
                         batch_size=64)
        # device 0's first upload (in flight 0 -> 0.22) is killed by the
        # outage opening at 0.1, every segment
        fs = FailureSchedule([FailureWindow(0, 0.1, 0.3),
                              FailureWindow(1, 1.0, 1.4)])
        specs = [
            DeviceSpec(DeviceProfile(i, 0.01 * (i + 1), 2.0 + i),
                       Plan(2, 0.1, 0.0, 0.02 * (i + 1) + 0.1 * (2.0 + i), 0),
                       "topk", True)
            for i in range(2)]
        return AFLSimulator(task, specs, "periodic", round_period=1.0,
                            eta_l=0.05, seed=0, failure_schedule=fs)

    def test_resume_replays_failure_segment_deterministically(self, tmp_path):
        from repro.checkpoint import CheckpointManager
        from repro.launch.train import fl_ckpt_state, restore_fl_state

        # uninterrupted: two segments on one simulator
        sim_a = self._sim()
        sim_a.run(total_rounds=4, eval_every=0)
        h_a = sim_a.run(total_rounds=8, eval_every=2)
        sim_a.close()

        # interrupted: segment 1, checkpoint, "crash", restore, segment 2
        sim_b = self._sim()
        sim_b.run(total_rounds=4, eval_every=0)
        assert sim_b.fault_counters()["crash_lost"] > 0  # faults were live
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        mgr.save(sim_b.model.round, fl_ckpt_state(sim_b))
        sim_b.close()

        sim_c = self._sim()
        restore_fl_state(sim_c, mgr.restore(mgr.latest_step()))
        assert sim_c.model.round == 4
        h_c = sim_c.run(total_rounds=8, eval_every=2)

        np.testing.assert_allclose(sim_c.model.w, sim_a.model.w,
                                   rtol=0, atol=2e-4)
        # identical event timelines: times/rounds/bits exact, metrics close
        # (drops excluded — the fresh sim's counters restart at zero)
        assert [(r.time, r.round) for r in h_c.records] == \
               [(r.time, r.round) for r in h_a.records]
        for rc, ra in zip(h_c.records, h_a.records):
            assert rc.loss == pytest.approx(ra.loss, abs=2e-3)
        assert h_c.counters["crash_lost"] > 0   # segment 2 replayed faults
        sim_c.close()


class TestStragglerMitigation:
    def test_async_round_never_blocks_on_straggler(self, task):
        """Periodic aggregation closes rounds on time even with a device
        100× slower than the round period."""
        from repro.core.controller import DeviceProfile
        from repro.core.factor import Plan
        fast = DeviceSpec(DeviceProfile(0, 0.01, 0.1), Plan(2, 0.5, 0, 0.1, 1))
        slow = DeviceSpec(DeviceProfile(1, 50.0, 0.1), Plan(2, 0.5, 0, 100, 100))
        sim = AFLSimulator(task, [fast, slow], "periodic", round_period=1.0,
                           seed=0)
        h = sim.run(total_rounds=10, eval_every=0)
        # 10 rounds complete in ~10s of simulated time despite the straggler
        assert sim.model.round >= 10
        assert h.records[-1].time <= 12.0
