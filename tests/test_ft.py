"""Fault tolerance: failures, stragglers, elastic membership, restart."""
import numpy as np
import pytest

from repro.core.simulator import (AFLSimulator, DeviceSpec, plan_devices,
                                  make_heterogeneous_devices)
from repro.ft import FailureSchedule, FailureWindow
from repro.models.small import make_task


@pytest.fixture(scope="module")
def task():
    return make_task("mlp_fmnist", num_samples=1000, test_samples=300,
                     batch_size=32)


class TestFailureSchedule:
    def test_is_down_semantics(self):
        fs = FailureSchedule([FailureWindow(0, 2.0, 5.0)])
        assert not fs.is_down(0, 1.9)
        assert fs.is_down(0, 2.0)
        assert fs.is_down(0, 4.99)
        assert not fs.is_down(0, 5.0)
        assert not fs.is_down(1, 3.0)

    def test_lost_in_flight(self):
        fs = FailureSchedule([FailureWindow(0, 2.0, 5.0)])
        assert fs.lost_in_flight(0, 1.0, 3.0)      # crash mid-upload
        assert not fs.lost_in_flight(0, 2.5, 4.0)  # started while down
        assert not fs.lost_in_flight(0, 5.5, 6.0)  # after recovery

    def test_recovery_time_chains_windows(self):
        fs = FailureSchedule([FailureWindow(0, 2.0, 5.0),
                              FailureWindow(0, 5.0, 7.0)])
        assert fs.recovery_time(0, 3.0) == 7.0

    def test_random_generator(self):
        fs = FailureSchedule.random(5, horizon=100.0, rate_per_device=1.0,
                                    seed=0)
        assert all(w.end > w.start for w in fs.windows)


class TestSimulatorUnderFailures:
    def test_training_survives_device_crashes(self, task):
        """AFL keeps converging when a device dies mid-run (its updates are
        simply absent from S^t — the core fault-tolerance property)."""
        profs = make_heterogeneous_devices(4, 3.2e6, seed=0)
        specs = plan_devices(profs, "fedluck", 1.0, k_bounds=(1, 8))
        fs = FailureSchedule([FailureWindow(0, 1.0, 6.0),
                              FailureWindow(1, 2.0, 4.0)])
        sim = AFLSimulator(task, specs, "periodic", round_period=1.0,
                           eta_l=0.05, seed=0, failure_schedule=fs)
        h = sim.run(total_rounds=14, eval_every=4)
        assert h.final_accuracy() > 0.7

    def test_failed_device_contributes_nothing_while_down(self, task):
        profs = make_heterogeneous_devices(2, 3.2e6, seed=1)
        specs = plan_devices(profs, "fedper", 1.0, fixed_k=2,
                             fixed_delta=0.5)
        fs = FailureSchedule([FailureWindow(0, 0.0, 1e9)])  # dev 0 always down
        sim = AFLSimulator(task, specs, "periodic", round_period=1.0,
                           seed=0, failure_schedule=fs)
        sim.run(total_rounds=6, eval_every=0)
        # only device 1's uploads were ever aggregated
        per_upload = specs[1].rate * sim.dim * 32
        assert sim.agg.total_bits % per_upload == 0


class TestStragglerMitigation:
    def test_async_round_never_blocks_on_straggler(self, task):
        """Periodic aggregation closes rounds on time even with a device
        100× slower than the round period."""
        from repro.core.controller import DeviceProfile
        from repro.core.factor import Plan
        fast = DeviceSpec(DeviceProfile(0, 0.01, 0.1), Plan(2, 0.5, 0, 0.1, 1))
        slow = DeviceSpec(DeviceProfile(1, 50.0, 0.1), Plan(2, 0.5, 0, 100, 100))
        sim = AFLSimulator(task, [fast, slow], "periodic", round_period=1.0,
                           seed=0)
        h = sim.run(total_rounds=10, eval_every=0)
        # 10 rounds complete in ~10s of simulated time despite the straggler
        assert sim.model.round >= 10
        assert h.records[-1].time <= 12.0
