import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression as C


def _g(d, seed=0):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randn(d).astype(np.float32)
                       * np.exp(rng.randn(d)))


class TestTopK:
    def test_keeps_exactly_k_largest(self):
        g = _g(1000)
        comp = C.topk(g, 0.05)
        k = C.num_keep(1000, 0.05)
        assert comp.values.shape == (k,)
        dense = np.asarray(comp.dense())
        mags = np.abs(np.asarray(g))
        thresh = np.sort(mags)[-k]
        kept = np.nonzero(dense)[0]
        assert len(kept) == k
        assert np.all(mags[kept] >= thresh - 1e-7)

    def test_dense_reconstruction_exact_on_support(self):
        g = _g(512, 1)
        comp = C.topk(g, 0.1)
        dense = np.asarray(comp.dense())
        idx = np.asarray(comp.indices)
        np.testing.assert_array_equal(dense[idx], np.asarray(g)[idx])

    def test_rate_one_is_identity(self):
        g = _g(128, 2)
        np.testing.assert_allclose(np.asarray(C.topk(g, 1.0).dense()),
                                   np.asarray(g), rtol=1e-6)

    @pytest.mark.parametrize("rate", [0.001, 0.01, 0.1, 0.5])
    def test_threshold_variant_close_to_exact(self, rate):
        g = _g(20000, 3)
        k = C.num_keep(20000, rate)
        t = C.topk_threshold(g, rate)
        nnz = int(np.count_nonzero(np.asarray(t.dense())))
        assert nnz <= k * 1.02 + 1
        assert nnz >= k * 0.85 - 1
        # support overlap with exact top-k
        exact = set(np.asarray(C.topk(g, rate).indices).tolist())
        ours = set(np.nonzero(np.asarray(t.dense()))[0].tolist())
        assert len(ours & exact) >= 0.85 * len(ours)


class TestErrorFeedback:
    def test_conservation(self):
        """comp.dense() + residual' == g + residual (nothing is lost)."""
        g, r = _g(400, 4), _g(400, 5) * 0.1
        comp, new_r = C.ef_compress(C.make_compressor("topk", 0.05), g, r)
        np.testing.assert_allclose(np.asarray(comp.dense() + new_r),
                                   np.asarray(g + r), rtol=1e-5, atol=1e-6)

    def test_residual_shrinks_error_over_rounds(self):
        """With EF, the accumulated transmitted signal tracks the true sum."""
        rng = np.random.RandomState(6)
        d, rounds = 300, 30
        comp = C.make_compressor("topk", 0.05)
        r = jnp.zeros(d)
        sent = np.zeros(d)
        total = np.zeros(d)
        for t in range(rounds):
            g = jnp.asarray(rng.randn(d).astype(np.float32))
            total += np.asarray(g)
            cc, r = C.ef_compress(comp, g, r)
            sent += np.asarray(cc.dense())
        # EF guarantees sent = total - residual  => error bounded by residual
        np.testing.assert_allclose(sent, total - np.asarray(r), rtol=1e-4,
                                   atol=1e-4)


class TestQuantizers:
    def test_signsgd_signs(self):
        g = _g(256, 7)
        d = np.asarray(C.signsgd(g).dense())
        assert np.all(np.sign(d[np.asarray(g) != 0])
                      == np.sign(np.asarray(g)[np.asarray(g) != 0]))

    def test_qsgd_bounded_error(self):
        g = _g(256, 8)
        d = np.asarray(C.qsgd(g, levels=256).dense())
        norm = float(jnp.linalg.norm(g))
        assert np.max(np.abs(d - np.asarray(g))) <= norm / 255 + 1e-5

    def test_randk_unbiased_scale(self):
        g = jnp.ones(100)
        comp = C.randk(g, 0.2, jax.random.PRNGKey(0))
        assert np.allclose(np.asarray(comp.values), 5.0)  # d/k = 5


class TestPytreeFlatten:
    def test_roundtrip(self):
        tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                "b": {"c": jnp.ones(4, jnp.bfloat16)}}
        flat, spec = C.flatten_pytree(tree)
        back = C.unflatten_pytree(flat, spec)
        assert back["a"].shape == (2, 3)
        assert back["b"]["c"].dtype == jnp.bfloat16
        np.testing.assert_allclose(np.asarray(back["a"]),
                                   np.asarray(tree["a"]))
