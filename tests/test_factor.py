import numpy as np
import pytest

from repro.core.factor import (phi, solve_plan, solve_plan_fixed_delta,
                               solve_plan_fixed_k, staleness)


class TestPhi:
    def test_matches_eq14_by_hand(self):
        # φ = ((kα+δβ)²(2−δ) + T²) / (T² k √δ)
        k, d, a, b, T = 10, 0.1, 0.05, 20.0, 1.0
        expected = ((k * a + d * b) ** 2 * (2 - d) + T * T) / (T * T * k
                                                               * np.sqrt(d))
        assert np.isclose(phi(k, d, a, b, T), expected)

    def test_staleness_eq(self):
        assert staleness(10, 0.1, 0.05, 20.0, 1.0) == np.ceil(2.5)


class TestSolver:
    def test_beats_brute_force_grid(self):
        a, b, T = 0.03, 15.0, 1.0
        plan = solve_plan(a, b, T, k_bounds=(1, 50),
                          delta_bounds=(1e-3, 1.0))
        ks = np.arange(1, 51)
        ds = np.geomspace(1e-3, 1.0, 500)
        K, D = np.meshgrid(ks, ds, indexing="ij")
        brute = phi(K, D, a, b, T).min()
        assert plan.phi <= brute * 1.001

    def test_respects_bounds(self):
        plan = solve_plan(0.5, 100.0, 1.0, k_bounds=(5, 8),
                          delta_bounds=(0.01, 0.02))
        assert 5 <= plan.k <= 8
        assert 0.01 <= plan.delta <= 0.02

    def test_slow_network_compresses_more(self):
        """Higher β (slower link) must push δ down (more compression)."""
        fast = solve_plan(0.02, 1.0, 1.0)
        slow = solve_plan(0.02, 200.0, 1.0)
        assert slow.delta < fast.delta

    def test_slow_compute_fewer_local_steps(self):
        """Higher α (slower device) must not increase k."""
        fast = solve_plan(0.005, 10.0, 1.0)
        slow = solve_plan(0.5, 10.0, 1.0)
        assert slow.k <= fast.k

    def test_fixed_variants_consistent(self):
        a, b, T = 0.05, 30.0, 1.0
        joint = solve_plan(a, b, T)
        lf = solve_plan_fixed_delta(a, b, T, delta=joint.delta)
        cr = solve_plan_fixed_k(a, b, T, k=joint.k)
        # fixing one coordinate at the joint optimum recovers (≈) the optimum
        assert lf.phi <= joint.phi * 1.01
        assert cr.phi <= joint.phi * 1.01
        # and the joint optimum is never worse
        assert joint.phi <= lf.phi * 1.001
        assert joint.phi <= cr.phi * 1.001

    def test_bad_bounds_raise(self):
        with pytest.raises(ValueError):
            solve_plan(0.1, 1.0, 1.0, delta_bounds=(0.0, 1.0))
        with pytest.raises(ValueError):
            solve_plan(0.1, 1.0, 1.0, k_bounds=(0, 5))
