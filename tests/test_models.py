"""Per-arch smoke tests (reduced configs): one forward/train step on CPU,
output shapes + no NaNs; numerics of attention/SSD vs oracles; prefill →
decode consistency (the serving invariant)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import SHAPES
from repro.models import mamba2 as m2
from repro.models.attention import (FULL_WINDOW, decode_attention,
                                    flash_attention, reference_attention)
from repro.models.transformer import LM


def _batch(cfg, B=2, S=64, seed=0):
    rng = np.random.RandomState(seed)
    if cfg.frontend == "frames":
        return {"frames": jnp.asarray(
                    rng.randn(B, S, cfg.frame_dim).astype(np.float32)),
                "labels": jnp.asarray(
                    rng.randint(0, cfg.vocab, (B, S)).astype(np.int32))}
    if cfg.frontend == "patches":
        text = S - cfg.n_patches
        return {"patches": jnp.asarray(
                    rng.randn(B, cfg.n_patches, cfg.patch_dim)
                    .astype(np.float32)),
                "tokens": jnp.asarray(
                    rng.randint(0, cfg.vocab, (B, text)).astype(np.int32)),
                "labels": jnp.asarray(
                    rng.randint(0, cfg.vocab, (B, text)).astype(np.int32))}
    return {"tokens": jnp.asarray(
                rng.randint(0, cfg.vocab, (B, S)).astype(np.int32)),
            "labels": jnp.asarray(
                rng.randint(0, cfg.vocab, (B, S)).astype(np.int32))}


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_train_step_shapes_and_finite(self, arch):
        cfg = get_config(arch).smoke()
        lm = LM(cfg, dtype=jnp.float32, remat=False)
        params = lm.init(jax.random.PRNGKey(0))
        batch = _batch(cfg)
        loss, grads = jax.jit(jax.value_and_grad(lm.loss))(params, batch)
        assert np.isfinite(float(loss))
        leaves = jax.tree.leaves(grads)
        assert all(np.isfinite(np.asarray(l)).all() for l in leaves)
        assert float(loss) > 0

    def test_full_config_dims_match_assignment(self, arch):
        cfg = get_config(arch)
        spec = {
            "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
            "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
            "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
            "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
            "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
            "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
            "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
            "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
            "mamba2-780m": (48, 1536, 0, 0, 0, 50280),
            "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
        }[arch]
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == spec

    def test_input_specs_are_abstract(self, arch):
        cfg = get_config(arch)
        for shape in cfg.shapes():
            specs = cfg.input_specs(shape)
            for v in jax.tree.leaves(specs):
                assert isinstance(v, jax.ShapeDtypeStruct)


class TestMoEArchs:
    @pytest.mark.parametrize("arch", ["grok-1-314b", "qwen3-moe-30b-a3b"])
    def test_moe_routes_to_topk_experts(self, arch):
        from repro.models.moe import moe_apply, moe_init
        cfg = get_config(arch).smoke()
        p = moe_init(jax.random.PRNGKey(0), cfg.d_model, cfg.d_ff,
                     cfg.n_experts)
        x = jnp.asarray(np.random.RandomState(0).randn(2, 8, cfg.d_model)
                        .astype(np.float32))
        y = moe_apply(p, x, n_experts=cfg.n_experts, top_k=cfg.moe_top_k,
                      dtype=jnp.float32)
        assert y.shape == x.shape
        assert np.isfinite(np.asarray(y)).all()

    def test_moe_capacity_drop_is_bounded(self):
        """At cf=1.25 with balanced-ish routing, most slots survive."""
        from repro.models.moe import moe_apply, moe_init
        d, E, k = 32, 4, 2
        p = moe_init(jax.random.PRNGKey(1), d, 64, E)
        x = jnp.asarray(np.random.RandomState(1).randn(4, 64, d)
                        .astype(np.float32))
        y_lo = moe_apply(p, x, n_experts=E, top_k=k, capacity_factor=1.25,
                         dtype=jnp.float32)
        y_hi = moe_apply(p, x, n_experts=E, top_k=k, capacity_factor=8.0,
                         dtype=jnp.float32)
        frac = float(jnp.mean(jnp.abs(y_lo - y_hi) > 1e-6))
        assert frac < 0.5  # most tokens unaffected by capacity


class TestAttentionNumerics:
    @pytest.mark.parametrize("window,prefix,causal", [
        (FULL_WINDOW, 0, True), (32, 0, True), (FULL_WINDOW, 17, True),
        (FULL_WINDOW, 0, False)])
    def test_flash_vs_reference(self, window, prefix, causal):
        rng = np.random.RandomState(0)
        q = jnp.asarray(rng.randn(2, 128, 4, 16).astype(np.float32))
        k = jnp.asarray(rng.randn(2, 128, 2, 16).astype(np.float32))
        v = jnp.asarray(rng.randn(2, 128, 2, 16).astype(np.float32))
        f = jax.jit(lambda q, k, v: flash_attention(
            q, k, v, causal=causal, window=jnp.int32(window),
            prefix_len=prefix, q_chunk=32, kv_chunk=32))(q, k, v)
        r = reference_attention(q, k, v, causal=causal, window=window,
                                prefix_len=prefix)
        np.testing.assert_allclose(np.asarray(f), np.asarray(r), atol=1e-4)

    def test_decode_matches_last_row(self):
        rng = np.random.RandomState(1)
        S, cur = 64, 40
        q = jnp.asarray(rng.randn(2, S, 4, 16).astype(np.float32))
        k = jnp.asarray(rng.randn(2, S, 2, 16).astype(np.float32))
        v = jnp.asarray(rng.randn(2, S, 2, 16).astype(np.float32))
        out = decode_attention(q[:, cur:cur + 1], k, v, jnp.int32(cur),
                               window=jnp.int32(FULL_WINDOW))
        r = reference_attention(q[:, :cur + 1], k[:, :cur + 1],
                                v[:, :cur + 1], causal=True)
        np.testing.assert_allclose(np.asarray(out[:, 0]),
                                   np.asarray(r[:, -1]), atol=1e-4)


class TestSSD:
    def test_chunked_vs_sequential(self):
        rng = np.random.RandomState(2)
        b, S, H, P, N = 2, 96, 3, 8, 4
        xh = jnp.asarray(rng.randn(b, S, H, P).astype(np.float32))
        dt = jnp.asarray(np.abs(rng.randn(b, S, H)).astype(np.float32) * 0.5)
        A = -jnp.asarray(np.abs(rng.randn(H)).astype(np.float32))
        Bm = jnp.asarray(rng.randn(b, S, N).astype(np.float32))
        Cm = jnp.asarray(rng.randn(b, S, N).astype(np.float32))
        y_c, st_c = m2.ssd_chunked(xh, dt * A, dt, Bm, Cm, chunk=32)
        y_r, st_r = m2.ssd_reference(xh, dt * A, dt, Bm, Cm)
        np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r),
                                   atol=2e-3, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(st_c), np.asarray(st_r),
                                   atol=2e-3, rtol=1e-3)

    def test_initial_state_carries(self):
        rng = np.random.RandomState(3)
        b, S, H, P, N = 1, 64, 2, 4, 4
        mk = lambda *s: jnp.asarray(rng.randn(*s).astype(np.float32))
        xh = mk(b, S, H, P)
        dt = jnp.abs(mk(b, S, H)) * 0.3
        A = -jnp.abs(mk(H))
        Bm, Cm = mk(b, S, N), mk(b, S, N)
        # full pass == two half passes chained via state
        y_full, st_full = m2.ssd_chunked(xh, dt * A, dt, Bm, Cm, chunk=16)
        y1, st1 = m2.ssd_chunked(xh[:, :32], (dt * A)[:, :32], dt[:, :32],
                                 Bm[:, :32], Cm[:, :32], chunk=16)
        y2, st2 = m2.ssd_chunked(xh[:, 32:], (dt * A)[:, 32:], dt[:, 32:],
                                 Bm[:, 32:], Cm[:, 32:], chunk=16,
                                 initial_state=st1)
        np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full),
                                   atol=2e-3, rtol=1e-3)
        np.testing.assert_allclose(
            np.asarray(jnp.concatenate([y1, y2], axis=1)),
            np.asarray(y_full), atol=2e-3, rtol=1e-3)


class TestPrefillDecodeConsistency:
    @pytest.mark.parametrize("arch", ["gemma3-4b", "hymba-1.5b",
                                      "mamba2-780m", "paligemma-3b",
                                      "qwen3-moe-30b-a3b"])
    def test_decode_equals_full_forward(self, arch):
        cfg = get_config(arch).smoke()
        if cfg.n_experts:
            # MoE capacity drops are load-dependent (a token may be dropped
            # in the full forward but never in single-token decode) — lift
            # the capacity so the consistency invariant is exact.
            cfg = dataclasses.replace(cfg, capacity_factor=8.0)
        lm = LM(cfg, dtype=jnp.float32, remat=False)
        params = lm.init(jax.random.PRNGKey(1))
        rng = np.random.RandomState(0)
        B, S = 2, 32
        batch = {k: v for k, v in _batch(cfg, B, S, 0).items()
                 if k != "labels"}
        logits_p, caches = jax.jit(lm.prefill)(params, batch)
        caches = {k: (jnp.concatenate(
            [v, jnp.zeros(v.shape[:2] + (4,) + v.shape[3:], v.dtype)],
            axis=2) if k in ("k", "v") else v) for k, v in caches.items()}
        nxt = jnp.asarray(rng.randint(0, cfg.vocab, (B, 1)).astype(np.int32))
        logits_d, _ = jax.jit(lm.decode_step)(params, caches, nxt,
                                              jnp.int32(S))
        if cfg.frontend == "patches":
            batch2 = dict(batch,
                          tokens=jnp.concatenate([batch["tokens"], nxt], 1))
        else:
            batch2 = {"tokens": jnp.concatenate([batch["tokens"], nxt], 1)}
        logits_f, _ = jax.jit(lm.prefill)(params, batch2)
        np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                                   np.asarray(logits_f[:, 0]), atol=2e-2)


class TestInt8KVCache:
    """int8 KV cache (decode bandwidth lever): per-(position, head) scales,
    s8×s8 dots — must track the bf16 path closely and never widen the
    cache."""

    def test_decode_matches_bf16_path(self):
        cfg = get_config("stablelm-3b").smoke()
        lm16 = LM(cfg, dtype=jnp.float32, remat=False)
        lm8 = dataclasses.replace(lm16, kv_dtype="int8")
        params = lm16.init(jax.random.PRNGKey(0))
        rng = np.random.RandomState(0)
        B, S = 2, 32
        tok = jnp.asarray(rng.randint(0, cfg.vocab, (B, S)), jnp.int32)
        c16, c8 = lm16.init_cache(B, S), lm8.init_cache(B, S)
        assert c8["k"].dtype == jnp.int8
        assert c8["k_scale"].shape == c8["k"].shape[:-1]
        d16 = jax.jit(lm16.decode_step)
        d8 = jax.jit(lm8.decode_step)
        for t in range(S):
            l16, c16 = d16(params, c16, tok[:, t:t + 1], jnp.int32(t))
            l8, c8 = d8(params, c8, tok[:, t:t + 1], jnp.int32(t))
        a, b = np.asarray(l16), np.asarray(l8)
        corr = np.corrcoef(a.ravel(), b.ravel())[0, 1]
        assert corr > 0.999, corr
        assert (a[:, -1].argmax(-1) == b[:, -1].argmax(-1)).all()

    def test_quantize_roundtrip_error_bounded(self):
        from repro.models.transformer import _quantize_kv
        rng = np.random.RandomState(1)
        x = jnp.asarray(rng.randn(2, 16, 4, 32).astype(np.float32)) * 3.0
        codes, scale = _quantize_kv(x)
        back = codes.astype(jnp.float32) * scale[..., None]
        err = np.abs(np.asarray(back - x))
        # error ≤ half a quantization step (= scale/2) elementwise
        assert (err <= np.asarray(scale)[..., None] * 0.5 + 1e-6).all()
